//! The summarized-PageRank executor: XLA dense path with sparse fallback.
//!
//! Given a [`SummaryGraph`], picks a backend:
//!
//! * **XLA dense** — if a runtime is attached and |K| fits an AOT
//!   capacity tier: densify + pad, then chain `run` artifacts (each
//!   `iters_fused` power iterations, returning the L1 delta) until the
//!   convergence epsilon or the iteration cap is reached. One `execute`
//!   round-trip per chunk (ablation A6 measures chunk-size sensitivity).
//! * **Rust sparse** — otherwise (or when no artifacts are available):
//!   the native executor in [`crate::pagerank::summarized`].
//!
//! Both produce identical semantics; integration tests cross-check them.

use crate::error::{Error, Result};
use crate::pagerank::power::PageRankConfig;
use crate::pagerank::summarized::{run_summarized, run_summarized_parallel, SummarizedResult};
use crate::runtime::artifact::Variant;
use crate::runtime::client::XlaRuntime;
use crate::summary::bigvertex::SummaryGraph;
use crate::util::threadpool::ThreadPool;

/// Which backend served a summarized computation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// PJRT-executed dense padded kernel at the given capacity.
    XlaDense { capacity: usize },
    /// Rust-native sparse executor.
    RustSparse,
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::XlaDense { capacity } => write!(f, "xla-dense(c{capacity})"),
            Backend::RustSparse => write!(f, "rust-sparse"),
        }
    }
}

/// Default |K| ceiling for routing to the XLA dense path.
///
/// Cost-aware backend choice: the padded dense kernel does O(C²) work per
/// iteration, the sparse executor O(|E_K|). On this CPU-PJRT +
/// interpret-mode setup the crossover sits near C = 256 (micro bench:
/// c128 ≈ 0.4 ms per 10 fused iterations, c512 ≈ 18 ms, c2048 ≈ 6.8 s vs
/// ≈1 ms sparse) — on a real TPU the MXU moves it far right (DESIGN.md
/// §Perf). Overridable via [`SummarizedExecutor::set_max_xla_k`] or the
/// `VEILGRAPH_MAX_XLA_K` env var.
pub const DEFAULT_MAX_XLA_K: usize = 256;

/// Executor with optional XLA runtime.
pub struct SummarizedExecutor {
    runtime: Option<XlaRuntime>,
    max_xla_k: usize,
}

fn default_max_xla_k() -> usize {
    std::env::var("VEILGRAPH_MAX_XLA_K")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_MAX_XLA_K)
}

impl SummarizedExecutor {
    /// Sparse-only executor (no artifacts required).
    pub fn sparse_only() -> Self {
        Self { runtime: None, max_xla_k: default_max_xla_k() }
    }

    /// Executor preferring the XLA path, with artifacts from `dir`.
    pub fn with_artifacts(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(Self { runtime: Some(XlaRuntime::new(dir)?), max_xla_k: default_max_xla_k() })
    }

    /// Wrap an existing runtime.
    pub fn with_runtime(runtime: XlaRuntime) -> Self {
        Self { runtime: Some(runtime), max_xla_k: default_max_xla_k() }
    }

    /// Route summaries with |K| ≤ `k` to the XLA dense path (`usize::MAX`
    /// = always when it fits a tier; 0 = never).
    pub fn set_max_xla_k(&mut self, k: usize) {
        self.max_xla_k = k;
    }

    /// Current routing ceiling.
    pub fn max_xla_k(&self) -> usize {
        self.max_xla_k
    }

    /// True if an XLA runtime is attached.
    pub fn has_xla(&self) -> bool {
        self.runtime.is_some()
    }

    /// Compile all tiers up front (off the query path).
    pub fn warmup(&mut self) -> Result<usize> {
        match &mut self.runtime {
            Some(rt) => rt.warmup(),
            None => Ok(0),
        }
    }

    /// Run the summarized computation, choosing the backend.
    pub fn execute(
        &mut self,
        s: &SummaryGraph,
        cfg: &PageRankConfig,
    ) -> Result<(SummarizedResult, Backend)> {
        self.execute_pooled(s, cfg, None)
    }

    /// Run the summarized computation, choosing the backend; when the
    /// sparse executor is picked and a pool is supplied (and
    /// `cfg.parallelism != 1`), the run is sharded across the pool via
    /// [`run_summarized_parallel`]. The pool is the engine's single
    /// worker pool — the same one the snapshot pipeline builds CSRs on,
    /// possibly shared across many engines by the experiment harness
    /// (sharding is a pure scheduling choice, so sharing changes no
    /// numbers). The dense path is untouched — it already batches its
    /// work into one kernel call per fused chunk.
    pub fn execute_pooled(
        &mut self,
        s: &SummaryGraph,
        cfg: &PageRankConfig,
        pool: Option<&ThreadPool>,
    ) -> Result<(SummarizedResult, Backend)> {
        let k = s.num_vertices();
        if k == 0 {
            let empty = SummarizedResult { ranks: vec![], iterations: 0, last_delta: 0.0 };
            return Ok((empty, Backend::RustSparse));
        }
        if let Some(rt) = &mut self.runtime {
            if k <= self.max_xla_k && k <= rt.max_capacity(Variant::Run) {
                let res = Self::execute_xla(rt, s, cfg)?;
                return Ok(res);
            }
        }
        let res = match pool {
            Some(pool) if cfg.parallelism != 1 => run_summarized_parallel(s, cfg, pool),
            _ => run_summarized(s, cfg),
        };
        Ok((res, Backend::RustSparse))
    }

    fn execute_xla(
        rt: &mut XlaRuntime,
        s: &SummaryGraph,
        cfg: &PageRankConfig,
    ) -> Result<(SummarizedResult, Backend)> {
        let k = s.num_vertices();
        let capacity = rt.ensure_tier(Variant::Run, k)?;
        let dense = s.to_dense(capacity);
        let teleport = cfg.teleport(s.full_n);
        let epsilon = cfg.scaled_epsilon(s.full_n);
        let chunk = rt.iters_fused().max(1);
        // Upload the per-summary constants (A is C² floats) to the device
        // ONCE; only the rank vector travels per fused chunk (§Perf).
        let prepared = rt.prepare_dense(
            capacity,
            &dense.a,
            &dense.b,
            &dense.mask,
            cfg.beta as f32,
            teleport as f32,
        )?;
        let mut ranks = dense.r0.clone();
        let mut iterations = 0usize;
        let mut last_delta = f64::INFINITY;
        while iterations < cfg.max_iters {
            let out = rt.execute_prepared(Variant::Run, &prepared, &ranks)?;
            ranks = out.ranks;
            iterations += chunk;
            last_delta = out
                .delta
                .ok_or_else(|| Error::Runtime("run artifact returned no delta".into()))?
                as f64;
            if cfg.epsilon > 0.0 && last_delta < epsilon {
                break;
            }
        }
        let ranks_f64: Vec<f64> = ranks[..k].iter().map(|&x| x as f64).collect();
        Ok((
            SummarizedResult { ranks: ranks_f64, iterations, last_delta },
            Backend::XlaDense { capacity },
        ))
    }
}

impl std::fmt::Debug for SummarizedExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SummarizedExecutor").field("has_xla", &self.has_xla()).finish()
    }
}
