//! PJRT runtime: artifact manifest, HLO-text loading/compilation, and
//! the backend-choosing summarized executor.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! entire request-path interface to the AOT-compiled L2/L1 stack.

pub mod artifact;
pub mod client;
pub mod executor;
