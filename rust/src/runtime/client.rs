//! PJRT runtime: loads AOT HLO-text artifacts and executes them.
//!
//! Exactly the wiring the reference (`/opt/xla-example/load_hlo.rs`)
//! validates: `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`. HLO
//! *text* is the interchange format (jax ≥ 0.5 emits 64-bit-id protos the
//! linked xla_extension 0.5.1 rejects; the text parser reassigns ids).
//!
//! Executables are compiled once per (variant, capacity) tier and cached
//! for the life of the process — compilation happens off the request
//! path, at engine start or on first use of a tier.

use std::collections::HashMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::runtime::artifact::{ArtifactEntry, Manifest, Variant};

/// Output of one summarized-PageRank execution on the PJRT path.
#[derive(Clone, Debug)]
pub struct StepOutput {
    /// Updated padded ranks (length = capacity; only the first `k` valid).
    pub ranks: Vec<f32>,
    /// L1 delta of the last fused iteration (`run` variant only).
    pub delta: Option<f32>,
}

/// A compiled executable for one (variant, capacity) tier.
struct Tier {
    exe: xla::PjRtLoadedExecutable,
    capacity: usize,
    outputs: usize,
}

/// The PJRT runtime: client + lazily compiled tier cache.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    tiers: HashMap<(Variant, usize), Tier>,
}

// SAFETY: the xla crate's PJRT wrappers use `Rc` and raw pointers, making
// them `!Send`. `XlaRuntime` owns its client and every executable compiled
// from it exclusively (no `Rc` handle ever escapes this struct), so moving
// the whole object graph to another thread — which is all the engine/server
// do; there is never concurrent access from two threads — is sound. The
// PJRT CPU client itself is thread-compatible.
unsafe impl Send for XlaRuntime {}

impl XlaRuntime {
    /// Create a CPU PJRT client and read the artifact manifest
    /// (compilation is deferred until a tier is first used, or
    /// [`Self::warmup`]).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, manifest, tiers: HashMap::new() })
    }

    /// The manifest describing available artifacts.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Iterations fused into each `run` artifact.
    pub fn iters_fused(&self) -> usize {
        self.manifest.iters_fused
    }

    /// Largest |K| the XLA path can serve for `variant`.
    pub fn max_capacity(&self, variant: Variant) -> usize {
        self.manifest.max_capacity(variant)
    }

    fn compile_entry(client: &xla::PjRtClient, entry: &ArtifactEntry) -> Result<Tier> {
        let path = entry.path.to_str().ok_or_else(|| {
            Error::Artifact(format!("non-utf8 artifact path {}", entry.path.display()))
        })?;
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(Tier { exe, capacity: entry.capacity, outputs: entry.outputs })
    }

    /// Ensure the tier for (variant, needed) is compiled; returns its
    /// capacity. Errors with [`Error::Capacity`] if `needed` exceeds every
    /// artifact (callers fall back to the sparse executor).
    pub fn ensure_tier(&mut self, variant: Variant, needed: usize) -> Result<usize> {
        let entry = self
            .manifest
            .pick_capacity(variant, needed)
            .ok_or(Error::Capacity { needed, max: self.manifest.max_capacity(variant) })?
            .clone();
        let key = (variant, entry.capacity);
        if !self.tiers.contains_key(&key) {
            let tier = Self::compile_entry(&self.client, &entry)?;
            self.tiers.insert(key, tier);
        }
        Ok(entry.capacity)
    }

    /// Compile every artifact up front (engine start; keeps compilation
    /// off the query path entirely).
    pub fn warmup(&mut self) -> Result<usize> {
        let entries: Vec<ArtifactEntry> = self.manifest.entries.clone();
        for e in &entries {
            let key = (e.variant, e.capacity);
            if !self.tiers.contains_key(&key) {
                self.tiers.insert(key, Self::compile_entry(&self.client, e)?);
            }
        }
        Ok(entries.len())
    }

    /// Execute one tier on padded dense inputs.
    ///
    /// * `a` — row-major `capacity × capacity` transition matrix.
    /// * `r`, `b`, `mask` — padded vectors of length `capacity`.
    /// * `beta`, `teleport` — the scalars operand `[β, (1-β)/n]`.
    ///
    /// The tier must have been compiled (`ensure_tier`/`warmup`) with
    /// capacity matching the input padding.
    pub fn execute(
        &self,
        variant: Variant,
        capacity: usize,
        a: &[f32],
        r: &[f32],
        b: &[f32],
        mask: &[f32],
        beta: f32,
        teleport: f32,
    ) -> Result<StepOutput> {
        let tier = self
            .tiers
            .get(&(variant, capacity))
            .ok_or_else(|| Error::Runtime(format!("tier ({variant:?}, {capacity}) not compiled")))?;
        let c = tier.capacity;
        if a.len() != c * c || r.len() != c || b.len() != c || mask.len() != c {
            return Err(Error::Runtime(format!(
                "input shape mismatch for capacity {c}: a={}, r={}, b={}, mask={}",
                a.len(),
                r.len(),
                b.len(),
                mask.len()
            )));
        }
        let a_lit = xla::Literal::vec1(a).reshape(&[c as i64, c as i64])?;
        let r_lit = xla::Literal::vec1(r);
        let b_lit = xla::Literal::vec1(b);
        let m_lit = xla::Literal::vec1(mask);
        let s_lit = xla::Literal::vec1(&[beta, teleport]);
        let result = tier.exe.execute::<xla::Literal>(&[a_lit, r_lit, b_lit, m_lit, s_lit])?[0][0]
            .to_literal_sync()?;
        // Lowered with return_tuple=True: unwrap the 1- or 2-tuple.
        if tier.outputs == 1 {
            let out = result.to_tuple1()?;
            Ok(StepOutput { ranks: out.to_vec::<f32>()?, delta: None })
        } else {
            let (ranks, delta) = result.to_tuple2()?;
            Ok(StepOutput {
                ranks: ranks.to_vec::<f32>()?,
                delta: Some(delta.get_first_element::<f32>()?),
            })
        }
    }
}

/// Device-resident operands for repeated executions over the same summary
/// (§Perf runtime-1): the A matrix (C² floats — 16 MiB at C = 2048), `b`,
/// `mask` and scalars are uploaded once; only the rank vector travels per
/// chunk when chaining fused-run artifacts to convergence.
pub struct PreparedDense {
    a: xla::PjRtBuffer,
    b: xla::PjRtBuffer,
    mask: xla::PjRtBuffer,
    scalars: xla::PjRtBuffer,
    capacity: usize,
}

impl XlaRuntime {
    /// Upload the per-summary constants to the device once.
    pub fn prepare_dense(
        &self,
        capacity: usize,
        a: &[f32],
        b: &[f32],
        mask: &[f32],
        beta: f32,
        teleport: f32,
    ) -> Result<PreparedDense> {
        if a.len() != capacity * capacity || b.len() != capacity || mask.len() != capacity {
            return Err(Error::Runtime(format!(
                "prepare_dense shape mismatch for capacity {capacity}"
            )));
        }
        Ok(PreparedDense {
            a: self.client.buffer_from_host_buffer(a, &[capacity, capacity], None)?,
            b: self.client.buffer_from_host_buffer(b, &[capacity], None)?,
            mask: self.client.buffer_from_host_buffer(mask, &[capacity], None)?,
            scalars: self.client.buffer_from_host_buffer(&[beta, teleport], &[2], None)?,
            capacity,
        })
    }

    /// Execute a tier against prepared device buffers, uploading only `r`.
    pub fn execute_prepared(
        &self,
        variant: Variant,
        prepared: &PreparedDense,
        r: &[f32],
    ) -> Result<StepOutput> {
        let c = prepared.capacity;
        let tier = self
            .tiers
            .get(&(variant, c))
            .ok_or_else(|| Error::Runtime(format!("tier ({variant:?}, {c}) not compiled")))?;
        if r.len() != c {
            return Err(Error::Runtime(format!("rank vector length {} != {c}", r.len())));
        }
        let r_buf = self.client.buffer_from_host_buffer(r, &[c], None)?;
        let args =
            [&prepared.a, &r_buf, &prepared.b, &prepared.mask, &prepared.scalars];
        let result = tier.exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0].to_literal_sync()?;
        if tier.outputs == 1 {
            let out = result.to_tuple1()?;
            Ok(StepOutput { ranks: out.to_vec::<f32>()?, delta: None })
        } else {
            let (ranks, delta) = result.to_tuple2()?;
            Ok(StepOutput {
                ranks: ranks.to_vec::<f32>()?,
                delta: Some(delta.get_first_element::<f32>()?),
            })
        }
    }
}

impl std::fmt::Debug for XlaRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaRuntime")
            .field("platform", &self.platform())
            .field("tiers", &self.tiers.keys().collect::<Vec<_>>())
            .finish()
    }
}
