//! Summarized-PageRank runtime: loads the AOT artifact manifest and
//! executes the dense padded kernels.
//!
//! The original wiring targeted PJRT through the `xla` crate
//! (`PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`), with HLO *text* as the interchange
//! format. That crate links a prebuilt `xla_extension` and cannot be
//! vendored into this std-only build, so this module ships a **native
//! fallback interpreter**: it validates the same `manifest.json` +
//! artifact files and executes the *identical* masked dense update the
//! lowered kernels implement,
//!
//! ```text
//! r'_z = mask_z · ( β · (Σ_u A[z,u] · r_u + b_z) + teleport )
//! ```
//!
//! in f32, fusing `iters_fused` iterations per `Run` call and returning
//! the final iteration's L1 delta — so every caller (executor routing,
//! engine, benches, integration tests) exercises the exact artifact
//! contract. Swapping the body back to PJRT is a local change: the
//! public surface (`XlaRuntime`, `StepOutput`, `PreparedDense`) is the
//! original one.
//!
//! "Executables" are validated once per (variant, capacity) tier and
//! cached for the life of the process — tier setup happens off the
//! request path, at engine start or on first use of a tier.

use std::collections::HashMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::runtime::artifact::{ArtifactEntry, Manifest, Variant};

/// Output of one summarized-PageRank execution on the runtime path.
#[derive(Clone, Debug)]
pub struct StepOutput {
    /// Updated padded ranks (length = capacity; only the first `k` valid).
    pub ranks: Vec<f32>,
    /// L1 delta of the last fused iteration (`run` variant only).
    pub delta: Option<f32>,
}

/// A validated executable for one (variant, capacity) tier.
#[derive(Clone, Debug)]
struct Tier {
    capacity: usize,
    outputs: usize,
    iters: usize,
}

/// The summarized runtime: manifest + lazily validated tier cache.
pub struct XlaRuntime {
    manifest: Manifest,
    tiers: HashMap<(Variant, usize), Tier>,
}

impl XlaRuntime {
    /// Read and validate the artifact manifest (tier setup is deferred
    /// until a tier is first used, or [`Self::warmup`]).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Self { manifest, tiers: HashMap::new() })
    }

    /// The manifest describing available artifacts.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Platform name (diagnostics). The `-native` suffix marks the
    /// fallback interpreter standing in for the PJRT CPU client.
    pub fn platform(&self) -> String {
        "cpu-native".to_string()
    }

    /// Iterations fused into each `run` artifact.
    pub fn iters_fused(&self) -> usize {
        self.manifest.iters_fused
    }

    /// Largest |K| the dense path can serve for `variant`.
    pub fn max_capacity(&self, variant: Variant) -> usize {
        self.manifest.max_capacity(variant)
    }

    /// "Compile" an entry: check the artifact file exists and is
    /// non-empty, mirroring the fail-fast behavior of the PJRT loader on
    /// a stale or partially written artifacts directory.
    fn compile_entry(entry: &ArtifactEntry, iters_fused: usize) -> Result<Tier> {
        let meta = std::fs::metadata(&entry.path).map_err(|e| {
            Error::Artifact(format!("cannot stat artifact {} ({e})", entry.path.display()))
        })?;
        if meta.len() == 0 {
            return Err(Error::Artifact(format!(
                "artifact {} is empty — rebuild with `make artifacts`",
                entry.path.display()
            )));
        }
        let iters = match entry.variant {
            Variant::Step => 1,
            Variant::Run => iters_fused.max(1),
        };
        Ok(Tier { capacity: entry.capacity, outputs: entry.outputs, iters })
    }

    /// Ensure the tier for (variant, needed) is ready; returns its
    /// capacity. Errors with [`Error::Capacity`] if `needed` exceeds every
    /// artifact (callers fall back to the sparse executor).
    pub fn ensure_tier(&mut self, variant: Variant, needed: usize) -> Result<usize> {
        let entry = self
            .manifest
            .pick_capacity(variant, needed)
            .ok_or(Error::Capacity { needed, max: self.manifest.max_capacity(variant) })?
            .clone();
        let key = (variant, entry.capacity);
        if !self.tiers.contains_key(&key) {
            let tier = Self::compile_entry(&entry, self.manifest.iters_fused)?;
            self.tiers.insert(key, tier);
        }
        Ok(entry.capacity)
    }

    /// Validate every artifact up front (engine start; keeps setup off
    /// the query path entirely).
    pub fn warmup(&mut self) -> Result<usize> {
        let entries: Vec<ArtifactEntry> = self.manifest.entries.clone();
        for e in &entries {
            let key = (e.variant, e.capacity);
            if !self.tiers.contains_key(&key) {
                self.tiers.insert(key, Self::compile_entry(e, self.manifest.iters_fused)?);
            }
        }
        Ok(entries.len())
    }

    /// One masked dense power iteration into `next`; returns the L1 delta
    /// against `r`.
    #[allow(clippy::too_many_arguments)]
    fn dense_iteration(
        c: usize,
        a: &[f32],
        r: &[f32],
        b: &[f32],
        mask: &[f32],
        beta: f32,
        teleport: f32,
        next: &mut [f32],
    ) -> f32 {
        let mut delta = 0.0f32;
        for z in 0..c {
            let row = &a[z * c..(z + 1) * c];
            let mut sum = 0.0f32;
            for (u, &w) in row.iter().enumerate() {
                sum += w * r[u];
            }
            let x = mask[z] * (beta * (sum + b[z]) + teleport);
            delta += (x - r[z]).abs();
            next[z] = x;
        }
        delta
    }

    fn run_tier(
        tier: &Tier,
        a: &[f32],
        r: &[f32],
        b: &[f32],
        mask: &[f32],
        beta: f32,
        teleport: f32,
    ) -> StepOutput {
        let c = tier.capacity;
        let mut ranks = r.to_vec();
        let mut next = vec![0.0f32; c];
        let mut delta = 0.0f32;
        for _ in 0..tier.iters {
            delta = Self::dense_iteration(c, a, &ranks, b, mask, beta, teleport, &mut next);
            std::mem::swap(&mut ranks, &mut next);
        }
        // Lowered with return_tuple=True: 1 output = ranks only,
        // 2 outputs = (ranks, delta).
        let delta = if tier.outputs >= 2 { Some(delta) } else { None };
        StepOutput { ranks, delta }
    }

    /// Execute one tier on padded dense inputs.
    ///
    /// * `a` — row-major `capacity × capacity` transition matrix.
    /// * `r`, `b`, `mask` — padded vectors of length `capacity`.
    /// * `beta`, `teleport` — the scalars operand `[β, (1-β)/n]`.
    ///
    /// The tier must have been prepared (`ensure_tier`/`warmup`) with
    /// capacity matching the input padding.
    #[allow(clippy::too_many_arguments)]
    pub fn execute(
        &self,
        variant: Variant,
        capacity: usize,
        a: &[f32],
        r: &[f32],
        b: &[f32],
        mask: &[f32],
        beta: f32,
        teleport: f32,
    ) -> Result<StepOutput> {
        let tier = self
            .tiers
            .get(&(variant, capacity))
            .ok_or_else(|| Error::Runtime(format!("tier ({variant:?}, {capacity}) not compiled")))?;
        let c = tier.capacity;
        if a.len() != c * c || r.len() != c || b.len() != c || mask.len() != c {
            return Err(Error::Runtime(format!(
                "input shape mismatch for capacity {c}: a={}, r={}, b={}, mask={}",
                a.len(),
                r.len(),
                b.len(),
                mask.len()
            )));
        }
        Ok(Self::run_tier(tier, a, r, b, mask, beta, teleport))
    }
}

/// Device-resident operands for repeated executions over the same summary
/// (§Perf runtime-1): on the PJRT path the A matrix (C² floats — 16 MiB
/// at C = 2048), `b`, `mask` and scalars are uploaded once and only the
/// rank vector travels per chunk. The native fallback keeps the same
/// shape: constants are captured once here, `execute_prepared` takes only
/// `r`.
pub struct PreparedDense {
    a: Vec<f32>,
    b: Vec<f32>,
    mask: Vec<f32>,
    beta: f32,
    teleport: f32,
    capacity: usize,
}

impl XlaRuntime {
    /// Capture the per-summary constants once.
    pub fn prepare_dense(
        &self,
        capacity: usize,
        a: &[f32],
        b: &[f32],
        mask: &[f32],
        beta: f32,
        teleport: f32,
    ) -> Result<PreparedDense> {
        if a.len() != capacity * capacity || b.len() != capacity || mask.len() != capacity {
            return Err(Error::Runtime(format!(
                "prepare_dense shape mismatch for capacity {capacity}"
            )));
        }
        Ok(PreparedDense {
            a: a.to_vec(),
            b: b.to_vec(),
            mask: mask.to_vec(),
            beta,
            teleport,
            capacity,
        })
    }

    /// Execute a tier against prepared constants, passing only `r`.
    pub fn execute_prepared(
        &self,
        variant: Variant,
        prepared: &PreparedDense,
        r: &[f32],
    ) -> Result<StepOutput> {
        let c = prepared.capacity;
        let tier = self
            .tiers
            .get(&(variant, c))
            .ok_or_else(|| Error::Runtime(format!("tier ({variant:?}, {c}) not compiled")))?;
        if r.len() != c {
            return Err(Error::Runtime(format!("rank vector length {} != {c}", r.len())));
        }
        Ok(Self::run_tier(
            tier,
            &prepared.a,
            r,
            &prepared.b,
            &prepared.mask,
            prepared.beta,
            prepared.teleport,
        ))
    }
}

impl std::fmt::Debug for XlaRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaRuntime")
            .field("platform", &self.platform())
            .field("tiers", &self.tiers.keys().collect::<Vec<_>>())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a minimal artifacts directory on disk for tier tests.
    fn fake_artifacts(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("vg-client-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("step_c4.hlo.txt"), "HloModule step\n").unwrap();
        std::fs::write(dir.join("run_c4.hlo.txt"), "HloModule run\n").unwrap();
        let manifest = r#"{
  "format": "hlo-text",
  "tile": 4,
  "iters_fused": 3,
  "artifacts": [
    {"name": "step_c4.hlo.txt", "variant": "step", "capacity": 4, "outputs": 1},
    {"name": "run_c4.hlo.txt", "variant": "run", "capacity": 4, "outputs": 2}
  ]
}"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        dir
    }

    #[test]
    fn step_matches_reference_formula() {
        let dir = fake_artifacts("step");
        let mut rt = XlaRuntime::new(&dir).unwrap();
        let cap = rt.ensure_tier(Variant::Step, 2).unwrap();
        assert_eq!(cap, 4);
        // A[0,1] = 0.5; r = e1; b[0] = 0.25; mask first two rows.
        let mut a = vec![0.0f32; cap * cap];
        a[1] = 0.5;
        let mut r = vec![0.0f32; cap];
        r[1] = 1.0;
        let mut b = vec![0.0f32; cap];
        b[0] = 0.25;
        let mut mask = vec![0.0f32; cap];
        mask[0] = 1.0;
        mask[1] = 1.0;
        let out = rt.execute(Variant::Step, cap, &a, &r, &b, &mask, 0.85, 0.01).unwrap();
        assert!(out.delta.is_none(), "step variant has a single output");
        // r'[0] = 0.85*(0.5 + 0.25) + 0.01 = 0.6475; r'[1] = 0.01; rest 0.
        assert!((out.ranks[0] - 0.6475).abs() < 1e-6, "{}", out.ranks[0]);
        assert!((out.ranks[1] - 0.01).abs() < 1e-6);
        assert!(out.ranks[2..].iter().all(|&x| x == 0.0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn run_fuses_iterations_and_reports_delta() {
        let dir = fake_artifacts("run");
        let mut rt = XlaRuntime::new(&dir).unwrap();
        let cap = rt.ensure_tier(Variant::Run, 2).unwrap();
        // Two-cycle between 0 and 1 converges toward 0.5 each.
        let mut a = vec![0.0f32; cap * cap];
        a[1] = 1.0;
        a[cap] = 1.0;
        let mut r = vec![0.0f32; cap];
        r[0] = 0.9;
        r[1] = 0.1;
        let b = vec![0.0f32; cap];
        let mut mask = vec![0.0f32; cap];
        mask[0] = 1.0;
        mask[1] = 1.0;
        let teleport = 0.15 / 2.0;
        let mut delta_prev = f32::INFINITY;
        // Error contracts by 0.85 per iteration from |r0 - 0.5| = 0.4, so
        // after 14 calls x 3 fused iters: 0.4 * 0.85^42 ≈ 4.3e-4 < 1e-3.
        for _ in 0..14 {
            let out = rt.execute(Variant::Run, cap, &a, &r, &b, &mask, 0.85, teleport).unwrap();
            r = out.ranks.clone();
            let d = out.delta.expect("run variant returns delta");
            assert!(d <= delta_prev + 1e-6, "delta must shrink: {d} vs {delta_prev}");
            delta_prev = d;
        }
        assert!((r[0] - 0.5).abs() < 1e-3, "{}", r[0]);
        assert!((r[1] - 0.5).abs() < 1e-3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prepared_path_matches_direct_execute() {
        let dir = fake_artifacts("prep");
        let mut rt = XlaRuntime::new(&dir).unwrap();
        let cap = rt.ensure_tier(Variant::Run, 3).unwrap();
        let mut a = vec![0.0f32; cap * cap];
        a[2] = 0.25;
        a[cap] = 0.75;
        let r = vec![0.3f32; cap];
        let b = vec![0.05f32; cap];
        let mask = vec![1.0f32, 1.0, 1.0, 0.0];
        let direct = rt.execute(Variant::Run, cap, &a, &r, &b, &mask, 0.85, 0.0375).unwrap();
        let prepared = rt.prepare_dense(cap, &a, &b, &mask, 0.85, 0.0375).unwrap();
        let via = rt.execute_prepared(Variant::Run, &prepared, &r).unwrap();
        assert_eq!(direct.ranks, via.ranks);
        assert_eq!(direct.delta, via.delta);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shape_mismatch_and_missing_tier_are_errors() {
        let dir = fake_artifacts("err");
        let mut rt = XlaRuntime::new(&dir).unwrap();
        let short = vec![0.0f32; 3];
        assert!(rt.execute(Variant::Step, 4, &short, &short, &short, &short, 0.85, 0.1).is_err());
        rt.ensure_tier(Variant::Step, 2).unwrap();
        assert!(rt.execute(Variant::Step, 4, &short, &short, &short, &short, 0.85, 0.1).is_err());
        assert!(matches!(
            rt.ensure_tier(Variant::Step, 99),
            Err(Error::Capacity { needed: 99, max: 4 })
        ));
        std::fs::remove_dir_all(&dir).ok();
    }
}
