//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime.
//!
//! `make artifacts` writes `artifacts/manifest.json` describing every
//! lowered HLO-text module (variant, capacity, output arity). The loader
//! validates the manifest before compiling anything so a stale or
//! partially-written artifacts directory fails fast with a clear error.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Artifact variants emitted by the AOT pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// One power iteration; 1 output (ranks).
    Step,
    /// `iters_fused` iterations; 2 outputs (ranks, L1 delta).
    Run,
}

impl Variant {
    fn parse(s: &str) -> Result<Variant> {
        match s {
            "step" => Ok(Variant::Step),
            "run" => Ok(Variant::Run),
            other => Err(Error::Artifact(format!("unknown variant {other:?}"))),
        }
    }
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub variant: Variant,
    pub capacity: usize,
    pub outputs: usize,
    pub path: PathBuf,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// MXU tile edge the kernel was built with.
    pub tile: usize,
    /// Iterations fused into each `run` artifact.
    pub iters_fused: usize,
    /// All artifacts, sorted by (variant, capacity).
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            ))
        })?;
        let json = Json::parse(&text)?;
        if json.get("format").and_then(Json::as_str) != Some("hlo-text") {
            return Err(Error::Artifact("manifest format must be hlo-text".into()));
        }
        let tile = json
            .get("tile")
            .and_then(Json::as_u64)
            .ok_or_else(|| Error::Artifact("manifest missing tile".into()))? as usize;
        let iters_fused = json
            .get("iters_fused")
            .and_then(Json::as_u64)
            .ok_or_else(|| Error::Artifact("manifest missing iters_fused".into()))?
            as usize;
        let arts = json
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Artifact("manifest missing artifacts".into()))?;
        let mut entries = Vec::with_capacity(arts.len());
        for a in arts {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Artifact("artifact missing name".into()))?
                .to_string();
            let variant = Variant::parse(
                a.get("variant")
                    .and_then(Json::as_str)
                    .ok_or_else(|| Error::Artifact(format!("{name}: missing variant")))?,
            )?;
            let capacity = a
                .get("capacity")
                .and_then(Json::as_u64)
                .ok_or_else(|| Error::Artifact(format!("{name}: missing capacity")))?
                as usize;
            let outputs = a.get("outputs").and_then(Json::as_u64).unwrap_or(1) as usize;
            if capacity == 0 || capacity % tile != 0 {
                return Err(Error::Artifact(format!(
                    "{name}: capacity {capacity} not a positive multiple of tile {tile}"
                )));
            }
            let path = dir.join(&name);
            if !path.is_file() {
                return Err(Error::Artifact(format!("missing artifact file {}", path.display())));
            }
            entries.push(ArtifactEntry { name, variant, capacity, outputs, path });
        }
        if entries.is_empty() {
            return Err(Error::Artifact("manifest lists no artifacts".into()));
        }
        entries.sort_by_key(|e| (e.variant != Variant::Step, e.capacity));
        Ok(Manifest { tile, iters_fused, entries })
    }

    /// Capacities available for `variant`, ascending.
    pub fn capacities(&self, variant: Variant) -> Vec<usize> {
        self.entries.iter().filter(|e| e.variant == variant).map(|e| e.capacity).collect()
    }

    /// Smallest capacity ≥ `needed` for `variant`.
    pub fn pick_capacity(&self, variant: Variant, needed: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.variant == variant && e.capacity >= needed)
            .min_by_key(|e| e.capacity)
    }

    /// Largest available capacity for `variant`.
    pub fn max_capacity(&self, variant: Variant) -> usize {
        self.capacities(variant).into_iter().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str, files: &[&str]) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
        for f in files {
            let mut fh = std::fs::File::create(dir.join(f)).unwrap();
            writeln!(fh, "HloModule fake").unwrap();
        }
    }

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("vg-artifact-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    const GOOD: &str = r#"{
      "format": "hlo-text", "tile": 128, "iters_fused": 10,
      "scalars_layout": ["beta", "teleport"],
      "artifacts": [
        {"name": "s128.hlo.txt", "variant": "step", "capacity": 128, "outputs": 1},
        {"name": "s256.hlo.txt", "variant": "step", "capacity": 256, "outputs": 1},
        {"name": "r128.hlo.txt", "variant": "run", "capacity": 128, "outputs": 2}
      ]
    }"#;

    #[test]
    fn loads_valid_manifest() {
        let d = tmpdir("good");
        write_manifest(&d, GOOD, &["s128.hlo.txt", "s256.hlo.txt", "r128.hlo.txt"]);
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.tile, 128);
        assert_eq!(m.iters_fused, 10);
        assert_eq!(m.capacities(Variant::Step), vec![128, 256]);
        assert_eq!(m.capacities(Variant::Run), vec![128]);
        assert_eq!(m.max_capacity(Variant::Step), 256);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn pick_capacity_selects_smallest_fit() {
        let d = tmpdir("pick");
        write_manifest(&d, GOOD, &["s128.hlo.txt", "s256.hlo.txt", "r128.hlo.txt"]);
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.pick_capacity(Variant::Step, 1).unwrap().capacity, 128);
        assert_eq!(m.pick_capacity(Variant::Step, 129).unwrap().capacity, 256);
        assert!(m.pick_capacity(Variant::Step, 257).is_none());
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn missing_file_fails_fast() {
        let d = tmpdir("missing");
        write_manifest(&d, GOOD, &["s128.hlo.txt", "s256.hlo.txt"]); // r128 absent
        let e = Manifest::load(&d).unwrap_err();
        assert!(e.to_string().contains("missing artifact file"), "{e}");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn unaligned_capacity_rejected() {
        let d = tmpdir("unaligned");
        let bad = GOOD.replace("\"capacity\": 256", "\"capacity\": 200");
        write_manifest(&d, &bad, &["s128.hlo.txt", "s256.hlo.txt", "r128.hlo.txt"]);
        let e = Manifest::load(&d).unwrap_err();
        assert!(e.to_string().contains("multiple of tile"), "{e}");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn absent_manifest_mentions_make_artifacts() {
        let d = tmpdir("absent");
        std::fs::create_dir_all(&d).unwrap();
        let e = Manifest::load(&d).unwrap_err();
        assert!(e.to_string().contains("make artifacts"), "{e}");
        std::fs::remove_dir_all(&d).ok();
    }
}
