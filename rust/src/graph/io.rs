//! Edge-list and stream file I/O.
//!
//! Format: tab- or whitespace-separated `src dst` per line, `#` comments,
//! exactly the layout of SNAP/LAW exports and of the paper's offline
//! stream files (§5: “for each dataset and stream size, we defined
//! (offline) a tab-separated file containing the stream of edge
//! additions”).

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::graph::generate::EdgeList;

/// Parse an edge list from a reader.
pub fn read_edges<R: std::io::Read>(r: R) -> Result<EdgeList> {
    let mut edges = Vec::new();
    for (lineno, line) in BufReader::new(r).lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut it = t.split_whitespace();
        let (u, v) = match (it.next(), it.next()) {
            (Some(u), Some(v)) => (u, v),
            _ => return Err(Error::Parse(format!("line {}: expected 'src dst'", lineno + 1))),
        };
        let u: u64 = u
            .parse()
            .map_err(|_| Error::Parse(format!("line {}: bad src {u:?}", lineno + 1)))?;
        let v: u64 = v
            .parse()
            .map_err(|_| Error::Parse(format!("line {}: bad dst {v:?}", lineno + 1)))?;
        edges.push((u, v));
    }
    Ok(edges)
}

/// Load an edge list from a file path.
pub fn load_edges(path: impl AsRef<Path>) -> Result<EdgeList> {
    read_edges(std::fs::File::open(path)?)
}

/// Write an edge list as TSV.
pub fn write_edges<W: Write>(w: W, edges: &[(u64, u64)], header: Option<&str>) -> Result<()> {
    let mut w = BufWriter::new(w);
    if let Some(h) = header {
        for line in h.lines() {
            writeln!(w, "# {line}")?;
        }
    }
    for &(u, v) in edges {
        writeln!(w, "{u}\t{v}")?;
    }
    w.flush()?;
    Ok(())
}

/// Save an edge list to a file path.
pub fn save_edges(
    path: impl AsRef<Path>,
    edges: &[(u64, u64)],
    header: Option<&str>,
) -> Result<()> {
    write_edges(std::fs::File::create(path)?, edges, header)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_with_header_and_comments() {
        let edges = vec![(1, 2), (3, 4), (1000000007, 5)];
        let mut buf = Vec::new();
        write_edges(&mut buf, &edges, Some("test graph\nsecond line")).unwrap();
        let text = String::from_utf8(buf.clone()).unwrap();
        assert!(text.starts_with("# test graph\n# second line\n"));
        let back = read_edges(&buf[..]).unwrap();
        assert_eq!(back, edges);
    }

    #[test]
    fn parses_mixed_whitespace_and_blank_lines() {
        let src = "\n# c\n1 2\n3\t4\n  5   6  \n";
        assert_eq!(read_edges(src.as_bytes()).unwrap(), vec![(1, 2), (3, 4), (5, 6)]);
    }

    #[test]
    fn reports_line_numbers_on_errors() {
        let e = read_edges("1 2\nxyz 4\n".as_bytes()).unwrap_err();
        assert!(e.to_string().contains("line 2"), "{e}");
        let e2 = read_edges("1\n".as_bytes()).unwrap_err();
        assert!(e2.to_string().contains("line 1"));
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("veilgraph-io-test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("edges.tsv");
        let edges = vec![(7, 8), (9, 10)];
        save_edges(&p, &edges, None).unwrap();
        assert_eq!(load_edges(&p).unwrap(), edges);
        std::fs::remove_file(&p).ok();
    }
}
