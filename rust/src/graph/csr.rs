//! Immutable CSR snapshot consumed by the PageRank kernels.
//!
//! Orientation: **pull**. Row `v` lists the *sources* of `v`'s in-edges,
//! and a parallel `out_degree` array stores each vertex's out-degree at
//! snapshot time — exactly the two pieces `r'_v = (1-β)/n + β·Σ r_u/d_u`
//! needs. (Ablation A4 compares against a push-oriented traversal.)

use crate::graph::VertexIdx;
use crate::util::threadpool::ThreadPool;

/// Compressed sparse row over in-edges + out-degree sidecar.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    offsets: Vec<u64>,
    targets: Vec<VertexIdx>,
    out_degree: Vec<u32>,
}

impl Csr {
    /// Assemble from raw parts. `offsets.len() == n+1`,
    /// `out_degree.len() == n`, `targets.len() == offsets[n]`.
    pub fn from_parts(offsets: Vec<u64>, targets: Vec<VertexIdx>, out_degree: Vec<u32>) -> Self {
        debug_assert_eq!(offsets.first().copied(), Some(0));
        debug_assert_eq!(*offsets.last().unwrap() as usize, targets.len());
        debug_assert_eq!(offsets.len(), out_degree.len() + 1);
        Self { offsets, targets, out_degree }
    }

    /// Build a pull CSR from a directed edge list over `n` dense vertices.
    /// Counting sort over destinations — O(n + m), no comparison sort.
    pub fn from_edges(n: usize, edges: &[(VertexIdx, VertexIdx)]) -> Self {
        let mut in_count = vec![0u64; n];
        let mut out_degree = vec![0u32; n];
        for &(s, d) in edges {
            in_count[d as usize] += 1;
            out_degree[s as usize] += 1;
        }
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0u64);
        for v in 0..n {
            offsets.push(offsets[v] + in_count[v]);
        }
        let mut cursor = offsets.clone();
        let mut targets = vec![0 as VertexIdx; edges.len()];
        for &(s, d) in edges {
            let c = &mut cursor[d as usize];
            targets[*c as usize] = s;
            *c += 1;
        }
        Self { offsets, targets, out_degree }
    }

    /// Parallel twin of [`Self::from_edges`] — the same counting sort,
    /// bit-identical output for any shard count, in three passes with no
    /// atomics and O(E + k·n) total work:
    ///
    /// 1. **Count** — each shard scans its own contiguous edge sub-range
    ///    into a private `2n`-wide counter block (in-counts, then
    ///    out-counts), merged serially per vertex into offsets.
    /// 2. **Bucket** — the same edge sub-ranges split their edges by
    ///    destination shard (in-degree-balanced cuts), preserving input
    ///    order within each bucket.
    /// 3. **Fill** — each destination shard owns a disjoint targets
    ///    slice and drains only its own buckets in edge-chunk order, so
    ///    every row receives its sources in input order — exactly the
    ///    serial build's order.
    ///
    /// Falls back to the serial build when no pool is given or
    /// `shards <= 1`.
    pub fn from_edges_pooled(
        n: usize,
        edges: &[(VertexIdx, VertexIdx)],
        pool: Option<&ThreadPool>,
        shards: usize,
    ) -> Self {
        let shards = shards.clamp(1, n.max(1));
        let pool = match pool {
            Some(p) if shards > 1 && n > 0 && !edges.is_empty() => p,
            _ => return Self::from_edges(n, edges),
        };
        // Shards beyond the pool's workers just queue, so cap them —
        // this also bounds the O(shards·n) counter block and the
        // shards² bucket Vecs below.
        let shards = shards.min(pool.size()).max(1);
        let echunk: Vec<usize> = (0..=shards).map(|i| i * edges.len() / shards).collect();
        let mut counts = vec![0u64; shards * 2 * n];
        let ccuts: Vec<usize> = (0..=shards).map(|i| i * 2 * n).collect();
        pool.scope_chunks(&mut counts, &ccuts, |i, block| {
            for &(s, d) in &edges[echunk[i]..echunk[i + 1]] {
                block[d as usize] += 1;
                block[n + s as usize] += 1;
            }
        });
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0u64);
        let mut out_degree = vec![0u32; n];
        for v in 0..n {
            let mut in_c = 0u64;
            let mut out_c = 0u64;
            for i in 0..shards {
                in_c += counts[i * 2 * n + v];
                out_c += counts[i * 2 * n + n + v];
            }
            offsets.push(offsets[v] + in_c);
            out_degree[v] = out_c as u32;
        }
        let cuts = balanced_cuts(n, shards, |v| offsets[v + 1] - offsets[v]);
        // Bucket pass: buckets[i][j] = chunk i's edges destined for
        // shard j, in input order.
        let mut buckets: Vec<Vec<Vec<(VertexIdx, VertexIdx)>>> =
            (0..shards).map(|_| vec![Vec::new(); shards]).collect();
        let bcuts: Vec<usize> = (0..=shards).collect();
        let cuts_ref = &cuts;
        pool.scope_chunks(&mut buckets, &bcuts, |i, slot| {
            let sets = &mut slot[0];
            for &(s, d) in &edges[echunk[i]..echunk[i + 1]] {
                let j = cuts_ref.partition_point(|&c| c <= d as usize) - 1;
                sets[j].push((s, d));
            }
        });
        let ecuts: Vec<usize> = cuts.iter().map(|&r| offsets[r] as usize).collect();
        let mut targets = vec![0 as VertexIdx; edges.len()];
        let offsets_ref = &offsets;
        let buckets_ref = &buckets;
        pool.scope_chunks(&mut targets, &ecuts, |j, chunk| {
            let lo = cuts_ref[j];
            let base = offsets_ref[lo];
            let mut cursor: Vec<u64> =
                offsets_ref[lo..cuts_ref[j + 1]].iter().map(|&o| o - base).collect();
            for sets in buckets_ref.iter() {
                for &(s, d) in &sets[j] {
                    let c = &mut cursor[d as usize - lo];
                    chunk[*c as usize] = s;
                    *c += 1;
                }
            }
        });
        Self { offsets, targets, out_degree }
    }

    /// Number of vertices.
    pub fn num_vertices(&self) -> usize {
        self.out_degree.len()
    }

    /// Number of edges.
    pub fn num_edges(&self) -> usize {
        self.targets.len()
    }

    /// Sources of `v`'s in-edges.
    #[inline]
    pub fn row(&self, v: VertexIdx) -> &[VertexIdx] {
        let lo = self.offsets[v as usize] as usize;
        let hi = self.offsets[v as usize + 1] as usize;
        &self.targets[lo..hi]
    }

    /// Contiguous targets slice spanning rows `lo..hi` (the sources of
    /// every in-edge of the range, row-major) — what incremental snapshot
    /// builds bulk-copy for runs of unchanged rows.
    #[inline]
    pub fn row_span(&self, lo: VertexIdx, hi: VertexIdx) -> &[VertexIdx] {
        let a = self.offsets[lo as usize] as usize;
        let b = self.offsets[hi as usize] as usize;
        &self.targets[a..b]
    }

    /// Out-degree of `v` at snapshot time.
    #[inline]
    pub fn out_degree(&self, v: VertexIdx) -> u32 {
        self.out_degree[v as usize]
    }

    /// The full out-degree array.
    pub fn out_degrees(&self) -> &[u32] {
        &self.out_degree
    }

    /// In-degree of `v`.
    #[inline]
    pub fn in_degree(&self, v: VertexIdx) -> u32 {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as u32
    }

    /// Dangling vertices (out-degree 0) count.
    pub fn num_dangling(&self) -> usize {
        self.out_degree.iter().filter(|&&d| d == 0).count()
    }

    /// Iterate `(src, dst)` pairs (dst = row owner).
    pub fn edges(&self) -> impl Iterator<Item = (VertexIdx, VertexIdx)> + '_ {
        (0..self.num_vertices() as VertexIdx)
            .flat_map(move |v| self.row(v).iter().map(move |&s| (s, v)))
    }

    /// Split the destination-vertex range into `k` contiguous shards
    /// balanced by **in-edge count**, not vertex count (a power-law graph
    /// splits evenly by vertices into shards whose gather work differs by
    /// orders of magnitude; balancing by edges is what makes the parallel
    /// executors scale — see `pagerank::power::PageRank::run_parallel`).
    ///
    /// Returns `k + 1` ascending cut points into vertex-index space:
    /// shard `i` owns rows `cuts[i]..cuts[i + 1]`, `cuts[0] == 0`,
    /// `cuts[k] == |V|`. Deterministic for a fixed `(graph, k)`, so
    /// sharded reductions have a stable order. `k` is clamped to
    /// `[1, |V|]` (trailing shards may be empty only when `|V| == 0`).
    pub fn shards(&self, k: usize) -> Vec<usize> {
        balanced_cuts(self.num_vertices(), k, |v| self.offsets[v + 1] - self.offsets[v])
    }
}

/// Cut `n` contiguous rows into `k` ranges of near-equal total weight,
/// where row `v` weighs `edge_count(v) + 1` (the `+ 1` accounts for the
/// per-vertex work — teleport, delta, write — and keeps edge-free
/// prefixes from collapsing into one giant shard). Shared by
/// [`Csr::shards`] and `summary::bigvertex::SummaryGraph::shards`.
///
/// Greedy with lookahead-free rebalancing: each shard takes rows until it
/// reaches `ceil(remaining_weight / remaining_shards)`, so early
/// heavyweight rows cannot starve later shards.
pub fn balanced_cuts(n: usize, k: usize, mut edge_count: impl FnMut(usize) -> u64) -> Vec<usize> {
    let k = k.clamp(1, n.max(1));
    let mut weights = Vec::with_capacity(n);
    let mut total: u64 = 0;
    for v in 0..n {
        let w = edge_count(v) + 1;
        weights.push(w);
        total += w;
    }
    let mut cuts = Vec::with_capacity(k + 1);
    cuts.push(0usize);
    let mut v = 0usize;
    let mut remaining = total;
    for s in 0..k {
        let shards_left = (k - s) as u64;
        let want = remaining.div_ceil(shards_left);
        // Leave at least one row for each of the later shards.
        let ceiling = n - (k - s - 1);
        let mut taken = 0u64;
        while v < ceiling && (taken < want || taken == 0) {
            taken += weights[v];
            v += 1;
        }
        remaining -= taken;
        cuts.push(v);
    }
    debug_assert_eq!(*cuts.last().unwrap(), n);
    cuts
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Csr {
        // 0->1, 0->2, 1->3, 2->3
        Csr::from_edges(4, &[(0, 1), (0, 2), (1, 3), (2, 3)])
    }

    #[test]
    fn from_edges_builds_pull_rows() {
        let c = diamond();
        assert_eq!(c.num_vertices(), 4);
        assert_eq!(c.num_edges(), 4);
        assert_eq!(c.row(0), &[] as &[u32]);
        assert_eq!(c.row(1), &[0]);
        assert_eq!(c.row(2), &[0]);
        let mut r3 = c.row(3).to_vec();
        r3.sort_unstable();
        assert_eq!(r3, vec![1, 2]);
    }

    #[test]
    fn degrees_are_consistent() {
        let c = diamond();
        assert_eq!(c.out_degree(0), 2);
        assert_eq!(c.out_degree(3), 0);
        assert_eq!(c.in_degree(3), 2);
        assert_eq!(c.num_dangling(), 1);
        let total_in: u32 = (0..4).map(|v| c.in_degree(v)).sum();
        let total_out: u32 = c.out_degrees().iter().sum();
        assert_eq!(total_in, total_out);
    }

    #[test]
    fn row_span_covers_contiguous_rows() {
        let c = diamond();
        assert_eq!(c.row_span(0, 4).len(), c.num_edges());
        assert_eq!(c.row_span(1, 3), &[0, 0]);
        assert_eq!(c.row_span(2, 2), &[] as &[u32]);
    }

    #[test]
    fn from_edges_pooled_is_bit_identical_to_serial() {
        let pool = ThreadPool::new(4);
        // skewed graph: hub row 0 plus a sprinkle of other edges
        let n = 120usize;
        let mut edges: Vec<(u32, u32)> = (1..n as u32).map(|v| (v, 0)).collect();
        for v in 0..n as u32 {
            edges.push((v, (v * 7 + 1) % n as u32));
        }
        let serial = Csr::from_edges(n, &edges);
        for shards in [1usize, 2, 4, 7, 100] {
            let par = Csr::from_edges_pooled(n, &edges, Some(&pool), shards);
            assert_eq!(par, serial, "shards={shards}");
        }
        // no pool falls back to serial; empty inputs are fine
        assert_eq!(Csr::from_edges_pooled(n, &edges, None, 8), serial);
        assert_eq!(Csr::from_edges_pooled(0, &[], Some(&pool), 4), Csr::from_edges(0, &[]));
        let iso = Csr::from_edges_pooled(5, &[], Some(&pool), 4);
        assert_eq!(iso, Csr::from_edges(5, &[]));
    }

    #[test]
    fn edges_iterator_roundtrips() {
        let c = diamond();
        let mut es: Vec<_> = c.edges().collect();
        es.sort_unstable();
        assert_eq!(es, vec![(0, 1), (0, 2), (1, 3), (2, 3)]);
    }

    #[test]
    fn empty_graph() {
        let c = Csr::from_edges(0, &[]);
        assert_eq!(c.num_vertices(), 0);
        assert_eq!(c.num_edges(), 0);
    }

    #[test]
    fn isolated_vertices_have_empty_rows() {
        let c = Csr::from_edges(5, &[(0, 4)]);
        for v in 1..4 {
            assert!(c.row(v).is_empty());
            assert_eq!(c.out_degree(v), 0);
        }
        assert_eq!(c.row(4), &[0]);
    }

    /// Shard weight (in-edges + 1 per row) for a cut range.
    fn shard_weight(c: &Csr, lo: usize, hi: usize) -> u64 {
        (lo..hi).map(|v| c.in_degree(v as u32) as u64 + 1).sum()
    }

    #[test]
    fn shards_partition_the_vertex_range() {
        let c = diamond();
        for k in 1..=6 {
            let cuts = c.shards(k);
            assert_eq!(cuts[0], 0);
            assert_eq!(*cuts.last().unwrap(), c.num_vertices());
            assert!(cuts.windows(2).all(|w| w[0] <= w[1]), "{cuts:?}");
            assert!(cuts.len() <= c.num_vertices() + 1, "k clamps to |V|");
        }
        assert_eq!(c.shards(1), vec![0, 4]);
    }

    #[test]
    fn shards_balance_by_in_edges_not_vertices() {
        // Vertex 0 receives an edge from everyone else; vertices 1..n-1
        // receive nothing. A vertex-count split would give shard 0 half
        // the edges plus half the vertices; the edge-balanced split must
        // put row 0 alone (its weight ≈ total/2 already).
        let n = 64usize;
        let edges: Vec<(u32, u32)> = (1..n as u32).map(|v| (v, 0)).collect();
        let c = Csr::from_edges(n, &edges);
        let cuts = c.shards(2);
        assert_eq!(cuts.len(), 3);
        let w0 = shard_weight(&c, cuts[0], cuts[1]);
        let w1 = shard_weight(&c, cuts[1], cuts[2]);
        let total = w0 + w1;
        assert!(cuts[1] < n / 4, "hub row must not drag half the vertices along: {cuts:?}");
        let ideal = total / 2;
        assert!(w0 <= ideal + n as u64 && w1 <= ideal + n as u64, "{w0} vs {w1}");
    }

    #[test]
    fn shards_are_deterministic_and_cover_skewed_graphs() {
        // Zipf-ish in-degrees: vertex v gets ~n/(v+1) in-edges.
        let n = 200usize;
        let mut edges = Vec::new();
        for v in 0..n {
            for s in 0..(n / (v + 1)).min(n - 1) {
                edges.push((((v + s + 1) % n) as u32, v as u32));
            }
        }
        let c = Csr::from_edges(n, &edges);
        for k in [1usize, 2, 3, 4, 7, 16] {
            let a = c.shards(k);
            let b = c.shards(k);
            assert_eq!(a, b, "shards must be deterministic");
            assert_eq!(a.len(), k + 1);
            // Every shard non-empty; no shard exceeds the greedy bound of
            // ideal + heaviest single row (contiguous sharding cannot
            // split one hub row across shards).
            let total = shard_weight(&c, 0, n);
            let max_row = (0..n).map(|v| c.in_degree(v as u32) as u64 + 1).max().unwrap();
            for w in a.windows(2) {
                assert!(w[1] > w[0], "empty shard in {a:?}");
                let sw = shard_weight(&c, w[0], w[1]);
                let bound = total.div_ceil(k as u64) + max_row + k as u64;
                assert!(sw <= bound, "shard {w:?} weight {sw} > bound {bound}");
            }
        }
    }

    #[test]
    fn shards_handle_degenerate_inputs() {
        let empty = Csr::from_edges(0, &[]);
        assert_eq!(empty.shards(4), vec![0, 0]);
        let single = Csr::from_edges(1, &[]);
        assert_eq!(single.shards(8), vec![0, 1]);
        // k larger than |V| clamps: every shard holds exactly one vertex
        let c = diamond();
        assert_eq!(c.shards(100), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn balanced_cuts_respects_weights() {
        // rows 0..3 weigh 1 each (+1), row 4 weighs 100 (+1)
        let cuts = balanced_cuts(5, 2, |v| if v == 4 { 100 } else { 1 });
        assert_eq!(cuts.len(), 3);
        // the heavy row must sit alone in the second shard
        assert_eq!(cuts, vec![0, 4, 5]);
    }
}
