//! Immutable CSR snapshot consumed by the PageRank kernels.
//!
//! Orientation: **pull**. Row `v` lists the *sources* of `v`'s in-edges,
//! and a parallel `out_degree` array stores each vertex's out-degree at
//! snapshot time — exactly the two pieces `r'_v = (1-β)/n + β·Σ r_u/d_u`
//! needs. (Ablation A4 compares against a push-oriented traversal.)

use crate::graph::VertexIdx;

/// Compressed sparse row over in-edges + out-degree sidecar.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    offsets: Vec<u64>,
    targets: Vec<VertexIdx>,
    out_degree: Vec<u32>,
}

impl Csr {
    /// Assemble from raw parts. `offsets.len() == n+1`,
    /// `out_degree.len() == n`, `targets.len() == offsets[n]`.
    pub fn from_parts(offsets: Vec<u64>, targets: Vec<VertexIdx>, out_degree: Vec<u32>) -> Self {
        debug_assert_eq!(offsets.first().copied(), Some(0));
        debug_assert_eq!(*offsets.last().unwrap() as usize, targets.len());
        debug_assert_eq!(offsets.len(), out_degree.len() + 1);
        Self { offsets, targets, out_degree }
    }

    /// Build a pull CSR from a directed edge list over `n` dense vertices.
    /// Counting sort over destinations — O(n + m), no comparison sort.
    pub fn from_edges(n: usize, edges: &[(VertexIdx, VertexIdx)]) -> Self {
        let mut in_count = vec![0u64; n];
        let mut out_degree = vec![0u32; n];
        for &(s, d) in edges {
            in_count[d as usize] += 1;
            out_degree[s as usize] += 1;
        }
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0u64);
        for v in 0..n {
            offsets.push(offsets[v] + in_count[v]);
        }
        let mut cursor = offsets.clone();
        let mut targets = vec![0 as VertexIdx; edges.len()];
        for &(s, d) in edges {
            let c = &mut cursor[d as usize];
            targets[*c as usize] = s;
            *c += 1;
        }
        Self { offsets, targets, out_degree }
    }

    /// Number of vertices.
    pub fn num_vertices(&self) -> usize {
        self.out_degree.len()
    }

    /// Number of edges.
    pub fn num_edges(&self) -> usize {
        self.targets.len()
    }

    /// Sources of `v`'s in-edges.
    #[inline]
    pub fn row(&self, v: VertexIdx) -> &[VertexIdx] {
        let lo = self.offsets[v as usize] as usize;
        let hi = self.offsets[v as usize + 1] as usize;
        &self.targets[lo..hi]
    }

    /// Out-degree of `v` at snapshot time.
    #[inline]
    pub fn out_degree(&self, v: VertexIdx) -> u32 {
        self.out_degree[v as usize]
    }

    /// The full out-degree array.
    pub fn out_degrees(&self) -> &[u32] {
        &self.out_degree
    }

    /// In-degree of `v`.
    #[inline]
    pub fn in_degree(&self, v: VertexIdx) -> u32 {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as u32
    }

    /// Dangling vertices (out-degree 0) count.
    pub fn num_dangling(&self) -> usize {
        self.out_degree.iter().filter(|&&d| d == 0).count()
    }

    /// Iterate `(src, dst)` pairs (dst = row owner).
    pub fn edges(&self) -> impl Iterator<Item = (VertexIdx, VertexIdx)> + '_ {
        (0..self.num_vertices() as VertexIdx)
            .flat_map(move |v| self.row(v).iter().map(move |&s| (s, v)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Csr {
        // 0->1, 0->2, 1->3, 2->3
        Csr::from_edges(4, &[(0, 1), (0, 2), (1, 3), (2, 3)])
    }

    #[test]
    fn from_edges_builds_pull_rows() {
        let c = diamond();
        assert_eq!(c.num_vertices(), 4);
        assert_eq!(c.num_edges(), 4);
        assert_eq!(c.row(0), &[] as &[u32]);
        assert_eq!(c.row(1), &[0]);
        assert_eq!(c.row(2), &[0]);
        let mut r3 = c.row(3).to_vec();
        r3.sort_unstable();
        assert_eq!(r3, vec![1, 2]);
    }

    #[test]
    fn degrees_are_consistent() {
        let c = diamond();
        assert_eq!(c.out_degree(0), 2);
        assert_eq!(c.out_degree(3), 0);
        assert_eq!(c.in_degree(3), 2);
        assert_eq!(c.num_dangling(), 1);
        let total_in: u32 = (0..4).map(|v| c.in_degree(v)).sum();
        let total_out: u32 = c.out_degrees().iter().sum();
        assert_eq!(total_in, total_out);
    }

    #[test]
    fn edges_iterator_roundtrips() {
        let c = diamond();
        let mut es: Vec<_> = c.edges().collect();
        es.sort_unstable();
        assert_eq!(es, vec![(0, 1), (0, 2), (1, 3), (2, 3)]);
    }

    #[test]
    fn empty_graph() {
        let c = Csr::from_edges(0, &[]);
        assert_eq!(c.num_vertices(), 0);
        assert_eq!(c.num_edges(), 0);
    }

    #[test]
    fn isolated_vertices_have_empty_rows() {
        let c = Csr::from_edges(5, &[(0, 4)]);
        for v in 1..4 {
            assert!(c.row(v).is_empty());
            assert_eq!(c.out_degree(v), 0);
        }
        assert_eq!(c.row(4), &[0]);
    }
}
