//! Vertex partitioning for the sharded multi-engine path.
//!
//! Two partitioning schemes, matching the two families the Besta et al.
//! streaming-graph survey catalogs:
//!
//! * **Hash partitioning** ([`Partitioner`]) — the scheme the live
//!   [`crate::coordinator::sharded::ShardedEngine`] uses. Every external
//!   vertex id maps to one owning shard through a splitmix64 bit mix, so
//!   assignment is *total* (every id owned by exactly one shard) and
//!   *stable under mutation* (the owner never changes as the graph
//!   evolves — no rebalancing, no routing table).
//! * **Contiguous row ranges** ([`split_rows`] / [`concat_rows`]) — the
//!   range-partitioned view of a frozen CSR, used by the re-concatenation
//!   property tests and anywhere a dense `[lo, hi)` slice of the vertex
//!   space is the natural shard shape (it is what
//!   [`crate::graph::csr::balanced_cuts`] produces for the parallel
//!   executors).
//!
//! Edges are routed by **source** vertex (a push-style edge partition):
//! the owner of `src` stores the edge, so every shard knows the *exact*
//! global out-degree of each vertex it owns — the quantity PageRank
//! divides rank mass by. The destination endpoint materializes in the
//! source owner's graph as a *ghost* (topology bookkeeping only; ghosts
//! never gain out-edges of their own), and a cross-shard edge
//! additionally notifies `dst`'s owner so the union of *owned* vertex
//! sets always equals the single-engine vertex set.

use crate::graph::csr::Csr;
use crate::graph::VertexId;
use crate::stream::event::EdgeOp;

/// Finalizer of the splitmix64 generator: a cheap, well-mixed 64-bit
/// permutation, so consecutive vertex ids (the common case for generated
/// datasets) spread uniformly over the shards instead of striping.
#[inline]
fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Total, mutation-stable hash assignment of external vertex ids to
/// `shards` owners.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Partitioner {
    shards: usize,
}

impl Partitioner {
    /// A partitioner over `shards` owners (clamped to ≥ 1).
    pub fn new(shards: usize) -> Self {
        Self { shards: shards.max(1) }
    }

    /// Number of shards ids are spread over.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The shard owning `id`. Pure function of `(id, shards)`: total over
    /// the whole id space and stable under any mutation sequence.
    #[inline]
    pub fn shard_of(&self, id: VertexId) -> usize {
        (mix64(id) % self.shards as u64) as usize
    }

    /// Route one op to the per-shard op lists it must reach, preserving
    /// the caller's op order within every shard:
    ///
    /// * `AddEdge(s, d)` / `RemoveEdge(s, d)` → the owner of `s` (the
    ///   edge lives with its source; `d` becomes a ghost there). A
    ///   cross-shard `AddEdge` also sends `AddVertex(d)` to `d`'s owner,
    ///   so the owned-vertex union matches the single-engine vertex set.
    /// * `AddVertex(v)` → the owner of `v`.
    /// * `RemoveVertex(v)` → **every** shard: the owner drops the vertex,
    ///   the rest drop their ghost copies and incident edges (shards
    ///   where `v` never appeared skip it as the usual no-op).
    pub fn for_each_route(&self, op: EdgeOp, mut deliver: impl FnMut(usize, EdgeOp)) {
        match op {
            EdgeOp::AddEdge(s, d) => {
                let owner = self.shard_of(s);
                deliver(owner, op);
                let dst_owner = self.shard_of(d);
                if dst_owner != owner {
                    deliver(dst_owner, EdgeOp::AddVertex(d));
                }
            }
            EdgeOp::RemoveEdge(s, _) => deliver(self.shard_of(s), op),
            EdgeOp::AddVertex(v) => deliver(self.shard_of(v), op),
            EdgeOp::RemoveVertex(_) => {
                for shard in 0..self.shards {
                    deliver(shard, op);
                }
            }
        }
    }

    /// [`Self::for_each_route`] appending into per-shard op lists.
    pub fn route_into(&self, op: EdgeOp, out: &mut [Vec<EdgeOp>]) {
        debug_assert_eq!(out.len(), self.shards);
        self.for_each_route(op, |shard, op| out[shard].push(op));
    }

    /// Route a batch: one op list per shard, per-shard order preserving
    /// the input order (so each shard's coalescer replays exactly the
    /// subsequence that concerns it).
    pub fn route(&self, ops: &[EdgeOp]) -> Vec<Vec<EdgeOp>> {
        let mut out = vec![Vec::new(); self.shards];
        for &op in ops {
            self.route_into(op, &mut out);
        }
        out
    }
}

/// Slice a CSR into contiguous row-range shards at `cuts` (as produced
/// by [`Csr::shards`] / [`crate::graph::csr::balanced_cuts`]:
/// `cuts[0] = 0`, `cuts[k] = |V|`). Each part keeps its rows' in-edge
/// lists verbatim — targets stay *global* source indices, exactly as the
/// parallel executors see their shard of the gather — with offsets
/// rebased to the part and the out-degree array sliced to its rows.
pub fn split_rows(csr: &Csr, cuts: &[usize]) -> Vec<Csr> {
    assert!(cuts.len() >= 2, "cuts must hold at least [0, |V|]");
    assert_eq!(cuts[0], 0);
    assert_eq!(*cuts.last().unwrap(), csr.num_vertices());
    let mut parts = Vec::with_capacity(cuts.len() - 1);
    for w in cuts.windows(2) {
        let (lo, hi) = (w[0], w[1]);
        let mut offsets = Vec::with_capacity(hi - lo + 1);
        let mut targets = Vec::new();
        let mut out_degree = Vec::with_capacity(hi - lo);
        offsets.push(0u64);
        for v in lo..hi {
            targets.extend_from_slice(csr.row(v as u32));
            offsets.push(targets.len() as u64);
            out_degree.push(csr.out_degree(v as u32));
        }
        parts.push(Csr::from_parts(offsets, targets, out_degree));
    }
    parts
}

/// Reassemble row-range shards (in order) into one CSR. Inverse of
/// [`split_rows`]: `concat_rows(&split_rows(csr, cuts))` reproduces
/// `csr` exactly, for any valid cut vector.
pub fn concat_rows(parts: &[Csr]) -> Csr {
    let n: usize = parts.iter().map(|p| p.num_vertices()).sum();
    let m: usize = parts.iter().map(|p| p.num_edges()).sum();
    let mut offsets = Vec::with_capacity(n + 1);
    let mut targets = Vec::with_capacity(m);
    let mut out_degree = Vec::with_capacity(n);
    offsets.push(0u64);
    for p in parts {
        for v in 0..p.num_vertices() as u32 {
            targets.extend_from_slice(p.row(v));
            offsets.push(targets.len() as u64);
            out_degree.push(p.out_degree(v));
        }
    }
    Csr::from_parts(offsets, targets, out_degree)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assignment_is_total_and_in_range() {
        for shards in 1..6 {
            let p = Partitioner::new(shards);
            for id in 0..500u64 {
                assert!(p.shard_of(id) < shards);
            }
        }
    }

    #[test]
    fn single_shard_owns_everything() {
        let p = Partitioner::new(1);
        for id in [0u64, 1, 17, u64::MAX] {
            assert_eq!(p.shard_of(id), 0);
        }
    }

    #[test]
    fn hash_spreads_consecutive_ids() {
        let p = Partitioner::new(4);
        let mut counts = [0usize; 4];
        for id in 0..4000u64 {
            counts[p.shard_of(id)] += 1;
        }
        for &c in &counts {
            assert!((700..=1300).contains(&c), "skewed shard: {counts:?}");
        }
    }

    #[test]
    fn routing_rules() {
        let p = Partitioner::new(3);
        // A cross-shard edge lands with the source owner plus an
        // AddVertex with the destination owner; a same-shard edge emits
        // exactly one op.
        let (s, d) = (0u64, 1u64);
        let routed = p.route(&[EdgeOp::AddEdge(s, d)]);
        let total: usize = routed.iter().map(Vec::len).sum();
        if p.shard_of(s) == p.shard_of(d) {
            assert_eq!(total, 1);
        } else {
            assert_eq!(total, 2);
            assert_eq!(routed[p.shard_of(s)], vec![EdgeOp::AddEdge(s, d)]);
            assert_eq!(routed[p.shard_of(d)], vec![EdgeOp::AddVertex(d)]);
        }
        // RemoveVertex broadcasts to every shard.
        let routed = p.route(&[EdgeOp::RemoveVertex(7)]);
        for ops in &routed {
            assert_eq!(ops, &vec![EdgeOp::RemoveVertex(7)]);
        }
        // RemoveEdge follows the source only.
        let routed = p.route(&[EdgeOp::RemoveEdge(s, d)]);
        assert_eq!(routed[p.shard_of(s)], vec![EdgeOp::RemoveEdge(s, d)]);
        let total: usize = routed.iter().map(Vec::len).sum();
        assert_eq!(total, 1);
    }

    #[test]
    fn split_then_concat_roundtrips() {
        let edges: Vec<(u32, u32)> = vec![(0, 1), (1, 2), (2, 0), (3, 1), (0, 3), (4, 4)];
        let csr = Csr::from_edges(5, &edges);
        for k in [1usize, 2, 3, 5] {
            let cuts = csr.shards(k);
            let parts = split_rows(&csr, &cuts);
            assert_eq!(concat_rows(&parts), csr, "k={k}");
        }
    }
}
