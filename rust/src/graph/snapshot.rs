//! Version-cached snapshot pipeline.
//!
//! The paper's update model treats the freeze step (graph → CSR) as cheap
//! next to rank computation; after PR 1 parallelized both executors it
//! became the largest serial fraction of every query. This module closes
//! that gap with three stacked levels, all producing bit-identical CSRs:
//!
//! 1. **Cached** — [`SnapshotCache`] keys the last-built CSR on
//!    [`DynamicGraph::version`]; a query against an unchanged graph reuses
//!    the same `Arc<Csr>` with zero allocations.
//! 2. **Incremental** — on a version miss, rows untouched since the
//!    cached build are bulk-copied from the old CSR
//!    ([`DynamicGraph::snapshot_from`]); only dirty rows re-read the
//!    adjacency lists.
//! 3. **Parallel** — both full and incremental rebuilds fan out over
//!    degree-balanced row ranges on the engine's shared [`ThreadPool`].
//!
//! One cache belongs to exactly ONE graph lineage: versions are per
//! instance, so feeding snapshots of diverged clones through a single
//! cache would pair a version number with the wrong topology. The engine
//! owns one cache per graph, which is the intended shape.

use std::sync::Arc;

use crate::graph::csr::Csr;
use crate::graph::dynamic::DynamicGraph;
use crate::util::threadpool::ThreadPool;

/// How a [`SnapshotCache::get`] call was served.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SnapshotBuild {
    /// Topology unchanged — the cached `Arc<Csr>` was handed back.
    CacheHit,
    /// Rebuilt reusing unchanged rows of the previous snapshot.
    Incremental,
    /// Built from scratch (first use, or after [`SnapshotCache::invalidate`]).
    Full,
}

/// Cumulative pipeline counters (monotonic over the cache's lifetime).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SnapshotStats {
    /// Calls served without any rebuild.
    pub hits: u64,
    /// Rebuilds that reused the previous snapshot.
    pub incremental: u64,
    /// Rebuilds from scratch.
    pub full: u64,
}

#[derive(Debug)]
struct CachedCsr {
    version: u64,
    csr: Arc<Csr>,
}

/// Version-keyed CSR cache over one [`DynamicGraph`] lineage.
#[derive(Debug, Default)]
pub struct SnapshotCache {
    cached: Option<CachedCsr>,
    stats: SnapshotStats,
}

impl SnapshotCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// The CSR for the graph's current topology: a shared handle on a
    /// version match, otherwise an incremental (or, cold, full) rebuild —
    /// parallel over `pool`/`shards` like [`DynamicGraph::snapshot_with`].
    pub fn get(
        &mut self,
        g: &DynamicGraph,
        pool: Option<&ThreadPool>,
        shards: usize,
    ) -> (Arc<Csr>, SnapshotBuild) {
        if let Some(c) = &self.cached {
            if c.version == g.version() {
                self.stats.hits += 1;
                return (Arc::clone(&c.csr), SnapshotBuild::CacheHit);
            }
        }
        let (csr, build) = match &self.cached {
            Some(c) => {
                self.stats.incremental += 1;
                (g.snapshot_from(&c.csr, c.version, pool, shards), SnapshotBuild::Incremental)
            }
            None => {
                self.stats.full += 1;
                (g.snapshot_with(pool, shards), SnapshotBuild::Full)
            }
        };
        let csr = Arc::new(csr);
        self.cached = Some(CachedCsr { version: g.version(), csr: Arc::clone(&csr) });
        (csr, build)
    }

    /// Drop the cached snapshot (next [`Self::get`] is a full build).
    pub fn invalidate(&mut self) {
        self.cached = None;
    }

    /// Cumulative counters.
    pub fn stats(&self) -> SnapshotStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seed_graph() -> DynamicGraph {
        DynamicGraph::from_edges(vec![(1, 2), (2, 3), (3, 1), (1, 3)]).0
    }

    #[test]
    fn unchanged_graph_is_a_pure_cache_hit() {
        let g = seed_graph();
        let mut cache = SnapshotCache::new();
        let (a, b1) = cache.get(&g, None, 1);
        assert_eq!(b1, SnapshotBuild::Full);
        let (b, b2) = cache.get(&g, None, 1);
        assert_eq!(b2, SnapshotBuild::CacheHit);
        assert!(Arc::ptr_eq(&a, &b), "hit must reuse the same allocation");
        assert_eq!(cache.stats(), SnapshotStats { hits: 1, incremental: 0, full: 1 });
    }

    #[test]
    fn mutation_triggers_incremental_rebuild_matching_fresh() {
        let mut g = seed_graph();
        let mut cache = SnapshotCache::new();
        let (old, _) = cache.get(&g, None, 1);
        g.add_edge(3, 2).unwrap();
        g.remove_edge(1, 2).unwrap();
        let (new, build) = cache.get(&g, None, 1);
        assert_eq!(build, SnapshotBuild::Incremental);
        assert_eq!(*new, g.snapshot());
        assert_ne!(*new, *old);
        assert_eq!(cache.stats().incremental, 1);
    }

    #[test]
    fn invalidate_forces_a_full_build() {
        let g = seed_graph();
        let mut cache = SnapshotCache::new();
        let _ = cache.get(&g, None, 1);
        cache.invalidate();
        let (_, build) = cache.get(&g, None, 1);
        assert_eq!(build, SnapshotBuild::Full);
        assert_eq!(cache.stats().full, 2);
    }

    #[test]
    fn parallel_cache_builds_match_serial() {
        let pool = ThreadPool::new(4);
        let mut g = seed_graph();
        let mut par = SnapshotCache::new();
        let mut ser = SnapshotCache::new();
        for round in 0..4u64 {
            g.add_edge(10 + round, round % 3 + 1).unwrap();
            let (a, _) = par.get(&g, Some(&pool), 4);
            let (b, _) = ser.get(&g, None, 1);
            assert_eq!(*a, *b, "round {round}");
        }
    }
}
