//! Graph substrate: dynamic directed graphs, CSR snapshots, traversal,
//! synthetic generators and edge-list I/O.
//!
//! Replaces Flink Gelly's graph layer in the paper's stack. The
//! [`dynamic::DynamicGraph`] is the mutable store the stream applies
//! updates to; [`csr::Csr`] is the frozen snapshot the PageRank kernels
//! consume (pull-based, so we store *in*-edges CSR plus an out-degree
//! array); [`snapshot::SnapshotCache`] is the version-keyed incremental
//! + parallel pipeline between the two.

pub mod csr;
pub mod dynamic;
pub mod generate;
pub mod io;
pub mod partition;
pub mod snapshot;
pub mod traversal;

/// Vertex identifier as seen by users (sparse, stable across updates).
pub type VertexId = u64;

/// Dense internal index after id-compaction (CSR position).
pub type VertexIdx = u32;
