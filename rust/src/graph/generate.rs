//! Deterministic synthetic graph generators.
//!
//! Substitute for the paper's LAW/SNAP datasets (unavailable offline; see
//! DESIGN.md §Substitutions). Each generator reproduces the *class* of
//! topology of the original: heavy-tailed in-degree for web graphs
//! (copying model), preferential attachment for social/co-author/
//! co-purchase networks, a time-layered DAG for citations, and a dense
//! core for the Facebook ego network. All are deterministic given a seed.

use crate::util::rng::Xoshiro256pp;

/// Edge list with user ids 0..n-1.
pub type EdgeList = Vec<(u64, u64)>;

fn dedupe(edges: &mut EdgeList) {
    let mut seen = std::collections::HashSet::with_capacity(edges.len());
    edges.retain(|&(u, v)| u != v && seen.insert((u, v)));
}

/// G(n, m) Erdős–Rényi: `m` distinct directed edges drawn uniformly.
pub fn erdos_renyi(n: usize, m: usize, seed: u64) -> EdgeList {
    assert!(n >= 2, "need at least 2 vertices");
    let max_m = n * (n - 1);
    let m = m.min(max_m);
    let mut rng = Xoshiro256pp::new(seed);
    let mut seen = std::collections::HashSet::with_capacity(m);
    let mut edges = Vec::with_capacity(m);
    while edges.len() < m {
        let u = rng.next_below(n as u64);
        let v = rng.next_below(n as u64);
        if u != v && seen.insert((u, v)) {
            edges.push((u, v));
        }
    }
    edges
}

/// Barabási–Albert preferential attachment. Each new vertex attaches
/// `m_per` edges to existing vertices chosen ∝ degree (repeated-endpoint
/// sampling over the edge list, the standard O(1) trick). `mutual_prob`
/// adds the reciprocal edge with that probability — social networks
/// (enron email, dblp co-authorship) are heavily reciprocal, web graphs
/// are not.
pub fn barabasi_albert(n: usize, m_per: usize, mutual_prob: f64, seed: u64) -> EdgeList {
    assert!(n > m_per && m_per >= 1);
    let mut rng = Xoshiro256pp::new(seed);
    let mut edges: EdgeList = Vec::with_capacity(n * m_per);
    // endpoint pool: every edge contributes both endpoints → degree-biased.
    let mut pool: Vec<u64> = Vec::with_capacity(2 * n * m_per);
    // seed clique over m_per+1 vertices
    for u in 0..=(m_per as u64) {
        let v = (u + 1) % (m_per as u64 + 1);
        edges.push((u, v));
        pool.push(u);
        pool.push(v);
    }
    for u in (m_per + 1)..n {
        let u = u as u64;
        let mut chosen = std::collections::BTreeSet::new();
        let mut guard = 0;
        while chosen.len() < m_per && guard < 50 * m_per {
            let t = pool[rng.range(0, pool.len())];
            if t != u {
                chosen.insert(t);
            }
            guard += 1;
        }
        for &t in &chosen {
            edges.push((u, t));
            pool.push(u);
            pool.push(t);
            if rng.chance(mutual_prob) {
                edges.push((t, u));
                pool.push(t);
                pool.push(u);
            }
        }
    }
    dedupe(&mut edges);
    edges
}

/// Kumar et al. copying model for web graphs: each new page links `d`
/// times; with probability `copy_prob` it copies the corresponding
/// out-link of a random prototype page, otherwise it links to a uniform
/// random page. Produces the power-law in-degree distribution measured on
/// real web crawls (cnr-2000, eu-2005).
pub fn copying_web(n: usize, d: usize, copy_prob: f64, seed: u64) -> EdgeList {
    assert!(n > d + 1 && d >= 1);
    let mut rng = Xoshiro256pp::new(seed);
    let mut out_adj: Vec<Vec<u64>> = Vec::with_capacity(n);
    // seed: a small cycle so every prototype has out-links
    let s = d + 1;
    for u in 0..s {
        let mut links = Vec::with_capacity(d);
        for k in 1..=d {
            links.push(((u + k) % s) as u64);
        }
        out_adj.push(links);
    }
    for u in s..n {
        let proto = rng.range(0, u);
        let mut links = Vec::with_capacity(d);
        for k in 0..d {
            let t = if rng.chance(copy_prob) && k < out_adj[proto].len() {
                out_adj[proto][k]
            } else {
                rng.next_below(u as u64)
            };
            links.push(t);
        }
        out_adj.push(links);
    }
    let mut edges: EdgeList = out_adj
        .iter()
        .enumerate()
        .flat_map(|(u, ls)| ls.iter().map(move |&v| (u as u64, v)))
        .collect();
    dedupe(&mut edges);
    edges
}

/// Citation DAG (Cit-HepPh stand-in): vertices arrive in time order and
/// cite `d`-ish earlier papers with preferential attachment; edges always
/// point backwards in time (a DAG, like real citation graphs).
pub fn citation_dag(n: usize, d: usize, seed: u64) -> EdgeList {
    assert!(n > d + 1 && d >= 1);
    let mut rng = Xoshiro256pp::new(seed);
    let mut edges: EdgeList = Vec::with_capacity(n * d);
    let mut pool: Vec<u64> = vec![0];
    for u in 1..n {
        let u = u as u64;
        let refs = 1 + rng.range(0, 2 * d - 1); // 1..2d citations, mean ~d
        let mut chosen = std::collections::BTreeSet::new();
        for _ in 0..refs {
            // 70 % preferential, 30 % uniform over the past
            let t = if rng.chance(0.7) {
                pool[rng.range(0, pool.len())]
            } else {
                rng.next_below(u)
            };
            if t < u {
                chosen.insert(t);
            }
        }
        for &t in &chosen {
            edges.push((u, t));
            pool.push(t);
        }
        pool.push(u);
    }
    dedupe(&mut edges);
    edges
}

/// Dense ego network (Facebook New Orleans stand-in): a dense core of
/// `core` vertices (each pair linked with prob `p_core`, both directions)
/// plus a periphery attaching preferentially to the core.
pub fn ego_network(n: usize, core: usize, p_core: f64, d_periph: usize, seed: u64) -> EdgeList {
    assert!(core < n && core >= 2);
    let mut rng = Xoshiro256pp::new(seed);
    let mut edges: EdgeList = Vec::new();
    for u in 0..core as u64 {
        for v in 0..core as u64 {
            if u != v && rng.chance(p_core) {
                edges.push((u, v));
            }
        }
    }
    for u in core as u64..n as u64 {
        let mut chosen = std::collections::BTreeSet::new();
        let mut guard = 0;
        while chosen.len() < d_periph && guard < 50 * d_periph {
            // 80 % to the core, 20 % to other periphery
            let t = if rng.chance(0.8) {
                rng.next_below(core as u64)
            } else {
                rng.next_below(u)
            };
            if t != u {
                chosen.insert(t);
            }
            guard += 1;
        }
        for &t in &chosen {
            edges.push((u, t));
            if rng.chance(0.6) {
                edges.push((t, u)); // friendship reciprocity
            }
        }
    }
    dedupe(&mut edges);
    edges
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dynamic::DynamicGraph;

    fn in_degree_tail(edges: &EdgeList, n: usize) -> (f64, usize) {
        let mut deg = vec![0usize; n];
        for &(_, v) in edges {
            deg[v as usize] += 1;
        }
        let max = *deg.iter().max().unwrap();
        let mean = edges.len() as f64 / n as f64;
        (mean, max)
    }

    #[test]
    fn er_produces_exact_count_distinct() {
        let e = erdos_renyi(100, 500, 1);
        assert_eq!(e.len(), 500);
        let set: std::collections::HashSet<_> = e.iter().collect();
        assert_eq!(set.len(), 500);
        assert!(e.iter().all(|&(u, v)| u != v && u < 100 && v < 100));
    }

    #[test]
    fn er_is_deterministic_per_seed() {
        assert_eq!(erdos_renyi(50, 100, 9), erdos_renyi(50, 100, 9));
        assert_ne!(erdos_renyi(50, 100, 9), erdos_renyi(50, 100, 10));
    }

    #[test]
    fn ba_heavy_tail() {
        let n = 2000;
        let e = barabasi_albert(n, 4, 0.5, 7);
        let (mean, max) = in_degree_tail(&e, n);
        // preferential attachment: max in-degree far above mean
        assert!(max as f64 > 10.0 * mean, "max {max} mean {mean}");
        // no dups/self-loops
        let set: std::collections::HashSet<_> = e.iter().collect();
        assert_eq!(set.len(), e.len());
        assert!(e.iter().all(|&(u, v)| u != v));
    }

    #[test]
    fn copying_web_heavy_tail_and_out_degree() {
        let n = 2000;
        let d = 8;
        let e = copying_web(n, d, 0.6, 3);
        let (mean, max) = in_degree_tail(&e, n);
        assert!(max as f64 > 10.0 * mean, "max {max} mean {mean}");
        // out-degree bounded by d
        let mut od = vec![0usize; n];
        for &(u, _) in &e {
            od[u as usize] += 1;
        }
        assert!(od.iter().all(|&x| x <= d));
    }

    #[test]
    fn citation_is_acyclic() {
        let e = citation_dag(500, 5, 11);
        assert!(e.iter().all(|&(u, v)| v < u), "edges must point back in time");
        let (g, dups) = DynamicGraph::from_edges(e.iter().copied());
        assert_eq!(dups, 0);
        assert!(g.num_edges() > 500);
    }

    #[test]
    fn ego_core_is_dense() {
        let e = ego_network(500, 50, 0.4, 4, 13);
        let core_edges = e.iter().filter(|&&(u, v)| u < 50 && v < 50).count();
        // expected ~ 0.4 * 50 * 49 ≈ 980
        assert!(core_edges > 600, "core {core_edges}");
        let set: std::collections::HashSet<_> = e.iter().collect();
        assert_eq!(set.len(), e.len());
    }

    #[test]
    fn all_generators_load_into_dynamic_graph() {
        for e in [
            erdos_renyi(100, 300, 1),
            barabasi_albert(100, 3, 0.3, 2),
            copying_web(100, 4, 0.5, 3),
            citation_dag(100, 3, 4),
            ego_network(100, 20, 0.3, 3, 5),
        ] {
            let (g, dups) = DynamicGraph::from_edges(e.iter().copied());
            assert_eq!(dups, 0, "generators must not emit duplicates");
            assert_eq!(g.num_edges(), e.len());
        }
    }
}
