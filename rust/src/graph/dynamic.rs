//! Mutable directed graph the update stream is applied to.
//!
//! Design notes:
//! * User-facing vertex ids are sparse `u64` (datasets keep their original
//!   ids); internally they compact to dense `u32` indices so CSR snapshots
//!   and rank vectors are flat arrays.
//! * Both out- and in-adjacency are maintained: PageRank pulls over
//!   in-edges, the hot-vertex expansion (Eqs. 3–4) walks neighborhoods in
//!   both directions, and degree deltas (Eq. 2) need out-degrees.
//! * Parallel edges are rejected (the paper's streams sample distinct
//!   edges); self-loops are allowed but excluded by the generators.
//! * Removal keeps the vertex slot (ids stay stable, as in the paper's
//!   model where a vertex's history matters across measurement points).

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::graph::csr::Csr;
use crate::graph::{VertexId, VertexIdx};

/// A growable directed graph with stable dense indices.
#[derive(Clone, Debug, Default)]
pub struct DynamicGraph {
    /// Sparse user id → dense index.
    index_of: HashMap<VertexId, VertexIdx>,
    /// Dense index → sparse user id.
    id_of: Vec<VertexId>,
    /// Out-adjacency per dense index.
    out_adj: Vec<Vec<VertexIdx>>,
    /// In-adjacency per dense index.
    in_adj: Vec<Vec<VertexIdx>>,
    /// Edge count.
    m: usize,
}

impl DynamicGraph {
    /// Empty graph.
    pub fn new() -> Self {
        Self::default()
    }

    /// Build from an iterator of (src, dst) pairs, adding vertices on the
    /// fly and ignoring duplicate edges (returns how many were ignored).
    pub fn from_edges<I: IntoIterator<Item = (VertexId, VertexId)>>(edges: I) -> (Self, usize) {
        let mut g = Self::new();
        let mut dups = 0;
        for (u, v) in edges {
            if g.add_edge(u, v).is_err() {
                dups += 1;
            }
        }
        (g, dups)
    }

    /// Number of vertices (including isolated ones).
    pub fn num_vertices(&self) -> usize {
        self.id_of.len()
    }

    /// Number of edges.
    pub fn num_edges(&self) -> usize {
        self.m
    }

    /// Dense index for a user id, if present.
    pub fn index(&self, id: VertexId) -> Option<VertexIdx> {
        self.index_of.get(&id).copied()
    }

    /// User id for a dense index.
    pub fn id(&self, idx: VertexIdx) -> VertexId {
        self.id_of[idx as usize]
    }

    /// Insert a vertex (no-op if present); returns its dense index.
    pub fn add_vertex(&mut self, id: VertexId) -> VertexIdx {
        if let Some(&i) = self.index_of.get(&id) {
            return i;
        }
        let idx = self.id_of.len() as VertexIdx;
        self.index_of.insert(id, idx);
        self.id_of.push(id);
        self.out_adj.push(Vec::new());
        self.in_adj.push(Vec::new());
        idx
    }

    /// Add a directed edge; vertices are created as needed.
    ///
    /// Errors with [`Error::Parse`] on duplicate edges (the caller decides
    /// whether duplicates are benign — `from_edges` counts and drops them).
    pub fn add_edge(&mut self, src: VertexId, dst: VertexId) -> Result<()> {
        let s = self.add_vertex(src);
        let d = self.add_vertex(dst);
        if self.out_adj[s as usize].contains(&d) {
            return Err(Error::Parse(format!("duplicate edge ({src}, {dst})")));
        }
        self.out_adj[s as usize].push(d);
        self.in_adj[d as usize].push(s);
        self.m += 1;
        Ok(())
    }

    /// Remove a directed edge.
    pub fn remove_edge(&mut self, src: VertexId, dst: VertexId) -> Result<()> {
        let s = self.index(src).ok_or(Error::UnknownVertex(src))?;
        let d = self.index(dst).ok_or(Error::UnknownVertex(dst))?;
        let out = &mut self.out_adj[s as usize];
        let pos = out.iter().position(|&x| x == d).ok_or(Error::UnknownEdge(src, dst))?;
        out.swap_remove(pos);
        let inn = &mut self.in_adj[d as usize];
        let pos = inn.iter().position(|&x| x == s).expect("in/out adjacency desync");
        inn.swap_remove(pos);
        self.m -= 1;
        Ok(())
    }

    /// Remove a vertex and all incident edges. The dense slot survives
    /// (ids remain stable) but becomes isolated.
    pub fn remove_vertex(&mut self, id: VertexId) -> Result<()> {
        let v = self.index(id).ok_or(Error::UnknownVertex(id))?;
        let outs: Vec<VertexIdx> = self.out_adj[v as usize].clone();
        for d in outs {
            let inn = &mut self.in_adj[d as usize];
            if let Some(p) = inn.iter().position(|&x| x == v) {
                inn.swap_remove(p);
                self.m -= 1;
            }
        }
        self.out_adj[v as usize].clear();
        let ins: Vec<VertexIdx> = self.in_adj[v as usize].clone();
        for s in ins {
            let out = &mut self.out_adj[s as usize];
            if let Some(p) = out.iter().position(|&x| x == v) {
                out.swap_remove(p);
                self.m -= 1;
            }
        }
        self.in_adj[v as usize].clear();
        Ok(())
    }

    /// True if the edge exists.
    pub fn has_edge(&self, src: VertexId, dst: VertexId) -> bool {
        match (self.index(src), self.index(dst)) {
            (Some(s), Some(d)) => self.out_adj[s as usize].contains(&d),
            _ => false,
        }
    }

    /// Out-degree by dense index.
    pub fn out_degree(&self, idx: VertexIdx) -> usize {
        self.out_adj[idx as usize].len()
    }

    /// In-degree by dense index.
    pub fn in_degree(&self, idx: VertexIdx) -> usize {
        self.in_adj[idx as usize].len()
    }

    /// Total degree (in + out) by dense index — the paper's `d_t(u)` uses
    /// the degree affected by incoming stream updates.
    pub fn degree(&self, idx: VertexIdx) -> usize {
        self.out_degree(idx) + self.in_degree(idx)
    }

    /// Out-neighbors by dense index.
    pub fn out_neighbors(&self, idx: VertexIdx) -> &[VertexIdx] {
        &self.out_adj[idx as usize]
    }

    /// In-neighbors by dense index.
    pub fn in_neighbors(&self, idx: VertexIdx) -> &[VertexIdx] {
        &self.in_adj[idx as usize]
    }

    /// Mean total degree over all vertices (`d̄` in Eq. 5).
    pub fn mean_degree(&self) -> f64 {
        if self.id_of.is_empty() {
            return 0.0;
        }
        // Every edge contributes one out- and one in-degree.
        2.0 * self.m as f64 / self.id_of.len() as f64
    }

    /// Freeze the current topology into a pull-oriented CSR snapshot:
    /// in-edge CSR plus out-degree array (what the power method consumes).
    pub fn snapshot(&self) -> Csr {
        let n = self.num_vertices();
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0u64);
        let mut targets = Vec::with_capacity(self.m);
        for v in 0..n {
            // CSR row v lists the *sources* of v's in-edges.
            targets.extend_from_slice(&self.in_adj[v]);
            offsets.push(targets.len() as u64);
        }
        let out_degree: Vec<u32> = (0..n).map(|v| self.out_adj[v].len() as u32).collect();
        Csr::from_parts(offsets, targets, out_degree)
    }

    /// Iterate over all edges as (src_idx, dst_idx).
    pub fn edges(&self) -> impl Iterator<Item = (VertexIdx, VertexIdx)> + '_ {
        self.out_adj
            .iter()
            .enumerate()
            .flat_map(|(s, outs)| outs.iter().map(move |&d| (s as VertexIdx, d)))
    }

    /// All user ids in dense order.
    pub fn ids(&self) -> &[VertexId] {
        &self.id_of
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> DynamicGraph {
        let (g, dups) = DynamicGraph::from_edges(vec![(10, 20), (20, 30), (30, 10)]);
        assert_eq!(dups, 0);
        g
    }

    #[test]
    fn add_edges_and_degrees() {
        let g = triangle();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 3);
        let i10 = g.index(10).unwrap();
        assert_eq!(g.out_degree(i10), 1);
        assert_eq!(g.in_degree(i10), 1);
        assert_eq!(g.degree(i10), 2);
        assert!((g.mean_degree() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn duplicate_edge_rejected() {
        let mut g = triangle();
        assert!(g.add_edge(10, 20).is_err());
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn duplicate_count_in_bulk_load() {
        let (g, dups) = DynamicGraph::from_edges(vec![(1, 2), (1, 2), (2, 3)]);
        assert_eq!(dups, 1);
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn remove_edge_updates_both_sides() {
        let mut g = triangle();
        g.remove_edge(10, 20).unwrap();
        assert_eq!(g.num_edges(), 2);
        assert!(!g.has_edge(10, 20));
        let i20 = g.index(20).unwrap();
        assert_eq!(g.in_degree(i20), 0);
        assert!(g.remove_edge(10, 20).is_err());
        assert!(g.remove_edge(99, 20).is_err());
    }

    #[test]
    fn remove_vertex_clears_incident_edges() {
        let mut g = triangle();
        g.add_edge(20, 10).unwrap();
        g.remove_vertex(20).unwrap();
        assert_eq!(g.num_edges(), 1); // only 30 -> 10 survives
        assert!(!g.has_edge(10, 20) && !g.has_edge(20, 30) && !g.has_edge(20, 10));
        // slot survives: id still resolvable, isolated
        let i20 = g.index(20).unwrap();
        assert_eq!(g.degree(i20), 0);
    }

    #[test]
    fn self_loop_allowed_once() {
        let mut g = DynamicGraph::new();
        g.add_edge(5, 5).unwrap();
        assert!(g.add_edge(5, 5).is_err());
        let i = g.index(5).unwrap();
        assert_eq!(g.out_degree(i), 1);
        assert_eq!(g.in_degree(i), 1);
    }

    #[test]
    fn snapshot_matches_topology() {
        let g = triangle();
        let csr = g.snapshot();
        assert_eq!(csr.num_vertices(), 3);
        assert_eq!(csr.num_edges(), 3);
        for v in 0..3u32 {
            let srcs = csr.row(v);
            assert_eq!(srcs.len(), g.in_degree(v));
            for &s in srcs {
                assert!(g.out_neighbors(s).contains(&v));
            }
            assert_eq!(csr.out_degree(v) as usize, g.out_degree(v));
        }
    }

    #[test]
    fn ids_survive_in_dense_order() {
        let g = triangle();
        assert_eq!(g.ids(), &[10, 20, 30]);
        assert_eq!(g.id(g.index(30).unwrap()), 30);
    }

    #[test]
    fn edges_iterator_covers_all() {
        let g = triangle();
        let es: Vec<_> = g.edges().collect();
        assert_eq!(es.len(), 3);
    }
}
