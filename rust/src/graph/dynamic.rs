//! Mutable directed graph the update stream is applied to.
//!
//! Design notes:
//! * User-facing vertex ids are sparse `u64` (datasets keep their original
//!   ids); internally they compact to dense `u32` indices so CSR snapshots
//!   and rank vectors are flat arrays.
//! * Both out- and in-adjacency are maintained: PageRank pulls over
//!   in-edges, the hot-vertex expansion (Eqs. 3–4) walks neighborhoods in
//!   both directions, and degree deltas (Eq. 2) need out-degrees.
//! * Parallel edges are rejected (the paper's streams sample distinct
//!   edges); self-loops are allowed but excluded by the generators.
//! * Removal keeps the vertex slot (ids stay stable, as in the paper's
//!   model where a vertex's history matters across measurement points).

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::graph::csr::{balanced_cuts, Csr};
use crate::graph::{VertexId, VertexIdx};
use crate::util::threadpool::ThreadPool;

/// A growable directed graph with stable dense indices.
#[derive(Clone, Debug, Default)]
pub struct DynamicGraph {
    /// Sparse user id → dense index.
    index_of: HashMap<VertexId, VertexIdx>,
    /// Dense index → sparse user id.
    id_of: Vec<VertexId>,
    /// Out-adjacency per dense index.
    out_adj: Vec<Vec<VertexIdx>>,
    /// In-adjacency per dense index.
    in_adj: Vec<Vec<VertexIdx>>,
    /// Edge count.
    m: usize,
    /// Topology version: bumped on every successful mutation (vertex
    /// insert, edge add/remove, vertex removal). Failed or no-op calls
    /// (duplicate edge, `add_vertex` of an existing id, unknown-edge
    /// removal) leave it untouched. Snapshot caches key on this.
    version: u64,
    /// Per-row stamp: the version at which `in_adj[v]` last changed
    /// (vertex creation counts). Incremental snapshot builds compare a
    /// row's stamp against the cached snapshot's version to decide
    /// whether the old CSR row can be bulk-copied.
    row_version: Vec<u64>,
}

impl DynamicGraph {
    /// Empty graph.
    pub fn new() -> Self {
        Self::default()
    }

    /// Build from an iterator of (src, dst) pairs, adding vertices on the
    /// fly and ignoring duplicate edges (returns how many were ignored).
    pub fn from_edges<I: IntoIterator<Item = (VertexId, VertexId)>>(edges: I) -> (Self, usize) {
        let mut g = Self::new();
        let mut dups = 0;
        for (u, v) in edges {
            if g.add_edge(u, v).is_err() {
                dups += 1;
            }
        }
        (g, dups)
    }

    /// Number of vertices (including isolated ones).
    pub fn num_vertices(&self) -> usize {
        self.id_of.len()
    }

    /// Number of edges.
    pub fn num_edges(&self) -> usize {
        self.m
    }

    /// Current topology version (0 for an empty graph; see the field
    /// docs for the bump rules).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Dense index for a user id, if present.
    pub fn index(&self, id: VertexId) -> Option<VertexIdx> {
        self.index_of.get(&id).copied()
    }

    /// User id for a dense index.
    pub fn id(&self, idx: VertexIdx) -> VertexId {
        self.id_of[idx as usize]
    }

    /// Insert a vertex (no-op if present); returns its dense index.
    pub fn add_vertex(&mut self, id: VertexId) -> VertexIdx {
        if let Some(&i) = self.index_of.get(&id) {
            return i;
        }
        let idx = self.id_of.len() as VertexIdx;
        self.index_of.insert(id, idx);
        self.id_of.push(id);
        self.out_adj.push(Vec::new());
        self.in_adj.push(Vec::new());
        self.version += 1;
        self.row_version.push(self.version);
        idx
    }

    /// Add a directed edge; vertices are created as needed.
    ///
    /// Errors with [`Error::Parse`] on duplicate edges (the caller decides
    /// whether duplicates are benign — `from_edges` counts and drops them).
    pub fn add_edge(&mut self, src: VertexId, dst: VertexId) -> Result<()> {
        let s = self.add_vertex(src);
        let d = self.add_vertex(dst);
        if self.out_adj[s as usize].contains(&d) {
            return Err(Error::Parse(format!("duplicate edge ({src}, {dst})")));
        }
        self.out_adj[s as usize].push(d);
        self.in_adj[d as usize].push(s);
        self.m += 1;
        self.version += 1;
        self.row_version[d as usize] = self.version;
        Ok(())
    }

    /// Remove a directed edge.
    pub fn remove_edge(&mut self, src: VertexId, dst: VertexId) -> Result<()> {
        let s = self.index(src).ok_or(Error::UnknownVertex(src))?;
        let d = self.index(dst).ok_or(Error::UnknownVertex(dst))?;
        let out = &mut self.out_adj[s as usize];
        let pos = out.iter().position(|&x| x == d).ok_or(Error::UnknownEdge(src, dst))?;
        out.swap_remove(pos);
        let inn = &mut self.in_adj[d as usize];
        let pos = inn.iter().position(|&x| x == s).expect("in/out adjacency desync");
        inn.swap_remove(pos);
        self.m -= 1;
        self.version += 1;
        self.row_version[d as usize] = self.version;
        Ok(())
    }

    /// Remove a vertex and all incident edges. The dense slot survives
    /// (ids remain stable) but becomes isolated.
    pub fn remove_vertex(&mut self, id: VertexId) -> Result<()> {
        let v = self.index(id).ok_or(Error::UnknownVertex(id))?;
        self.version += 1;
        let outs: Vec<VertexIdx> = self.out_adj[v as usize].clone();
        for d in outs {
            let inn = &mut self.in_adj[d as usize];
            if let Some(p) = inn.iter().position(|&x| x == v) {
                inn.swap_remove(p);
                self.m -= 1;
                self.row_version[d as usize] = self.version;
            }
        }
        self.out_adj[v as usize].clear();
        let ins: Vec<VertexIdx> = self.in_adj[v as usize].clone();
        for s in ins {
            let out = &mut self.out_adj[s as usize];
            if let Some(p) = out.iter().position(|&x| x == v) {
                out.swap_remove(p);
                self.m -= 1;
            }
        }
        self.in_adj[v as usize].clear();
        self.row_version[v as usize] = self.version;
        Ok(())
    }

    /// True if the edge exists.
    pub fn has_edge(&self, src: VertexId, dst: VertexId) -> bool {
        match (self.index(src), self.index(dst)) {
            (Some(s), Some(d)) => self.out_adj[s as usize].contains(&d),
            _ => false,
        }
    }

    /// Out-degree by dense index.
    pub fn out_degree(&self, idx: VertexIdx) -> usize {
        self.out_adj[idx as usize].len()
    }

    /// In-degree by dense index.
    pub fn in_degree(&self, idx: VertexIdx) -> usize {
        self.in_adj[idx as usize].len()
    }

    /// Total degree (in + out) by dense index — the paper's `d_t(u)` uses
    /// the degree affected by incoming stream updates.
    pub fn degree(&self, idx: VertexIdx) -> usize {
        self.out_degree(idx) + self.in_degree(idx)
    }

    /// Out-neighbors by dense index.
    pub fn out_neighbors(&self, idx: VertexIdx) -> &[VertexIdx] {
        &self.out_adj[idx as usize]
    }

    /// In-neighbors by dense index.
    pub fn in_neighbors(&self, idx: VertexIdx) -> &[VertexIdx] {
        &self.in_adj[idx as usize]
    }

    /// Mean total degree over all vertices (`d̄` in Eq. 5).
    pub fn mean_degree(&self) -> f64 {
        if self.id_of.is_empty() {
            return 0.0;
        }
        // Every edge contributes one out- and one in-degree.
        2.0 * self.m as f64 / self.id_of.len() as f64
    }

    /// Freeze the current topology into a pull-oriented CSR snapshot:
    /// in-edge CSR plus out-degree array (what the power method consumes).
    /// CSR row `v` lists the *sources* of `v`'s in-edges. Serial full
    /// build; see [`Self::snapshot_with`] / [`Self::snapshot_from`] for
    /// the parallel and incremental variants (all three are bit-identical
    /// for the same topology).
    pub fn snapshot(&self) -> Csr {
        self.build_snapshot(None, None, 1)
    }

    /// Full snapshot rebuild, parallel when a pool is supplied and
    /// `shards > 1`: a two-pass build over `shards` in-degree-balanced
    /// row ranges (pass 1 computes per-range offset prefix sums, pass 2
    /// fills disjoint `targets` slices). Bit-identical to
    /// [`Self::snapshot`] for every shard count.
    pub fn snapshot_with(&self, pool: Option<&ThreadPool>, shards: usize) -> Csr {
        self.build_snapshot(None, pool, shards)
    }

    /// Incremental snapshot rebuild: rows untouched since `prev_version`
    /// are bulk-copied from `prev` (runs of clean rows collapse into one
    /// `copy_from_slice`); dirty rows re-read the live adjacency. Offsets
    /// and out-degrees are always rebuilt (O(n) — cheap next to the edge
    /// fill). Contract: `prev` MUST be a snapshot THIS graph produced at
    /// version `prev_version` ([`crate::graph::snapshot::SnapshotCache`]
    /// enforces the pairing; diverged clones sharing version numbers
    /// would silently corrupt rows).
    pub fn snapshot_from(
        &self,
        prev: &Csr,
        prev_version: u64,
        pool: Option<&ThreadPool>,
        shards: usize,
    ) -> Csr {
        self.build_snapshot(Some((prev, prev_version)), pool, shards)
    }

    /// The one snapshot builder behind the three public variants.
    fn build_snapshot(
        &self,
        prev: Option<(&Csr, u64)>,
        pool: Option<&ThreadPool>,
        shards: usize,
    ) -> Csr {
        let n = self.num_vertices();
        let shards = shards.clamp(1, n.max(1));
        let mut offsets = vec![0u64; n + 1];
        let mut out_degree = vec![0u32; n];
        let mut targets = vec![0 as VertexIdx; self.m];
        match pool {
            Some(pool) if shards > 1 && n > 0 => {
                let cuts = balanced_cuts(n, shards, |v| self.in_adj[v].len() as u64);
                // Pass 1: per-range local prefix sums of in-degrees, then
                // rebase each range by the exclusive scan of range totals.
                let totals = pool.scope_chunks(&mut offsets[1..], &cuts, |i, chunk| {
                    let lo = cuts[i];
                    let mut run = 0u64;
                    for (off, slot) in chunk.iter_mut().enumerate() {
                        run += self.in_adj[lo + off].len() as u64;
                        *slot = run;
                    }
                    run
                });
                let mut bases = vec![0u64; cuts.len()];
                for (i, t) in totals.iter().enumerate() {
                    bases[i + 1] = bases[i] + t;
                }
                pool.scope_chunks(&mut offsets[1..], &cuts, |i, chunk| {
                    if bases[i] > 0 {
                        for slot in chunk.iter_mut() {
                            *slot += bases[i];
                        }
                    }
                });
                pool.scope_chunks(&mut out_degree, &cuts, |i, chunk| {
                    let lo = cuts[i];
                    for (off, slot) in chunk.iter_mut().enumerate() {
                        *slot = self.out_adj[lo + off].len() as u32;
                    }
                });
                // Pass 2: each range owns a disjoint targets slice (row
                // cuts mapped through the now-final offsets).
                let ecuts: Vec<usize> = cuts.iter().map(|&r| offsets[r] as usize).collect();
                let offsets_ref = &offsets;
                pool.scope_chunks(&mut targets, &ecuts, |i, chunk| {
                    self.fill_rows(chunk, cuts[i], cuts[i + 1], offsets_ref, prev);
                });
            }
            _ => {
                let mut run = 0u64;
                for v in 0..n {
                    run += self.in_adj[v].len() as u64;
                    offsets[v + 1] = run;
                    out_degree[v] = self.out_adj[v].len() as u32;
                }
                self.fill_rows(&mut targets, 0, n, &offsets, prev);
            }
        }
        Csr::from_parts(offsets, targets, out_degree)
    }

    /// Fill `chunk` — the targets slice for rows `lo..hi`, based at
    /// `offsets[lo]` — copying runs of unchanged rows from `prev` in bulk
    /// and re-reading dirty rows from the live adjacency.
    fn fill_rows(
        &self,
        chunk: &mut [VertexIdx],
        lo: usize,
        hi: usize,
        offsets: &[u64],
        prev: Option<(&Csr, u64)>,
    ) {
        let base = offsets[lo] as usize;
        let clean = |v: usize| match prev {
            Some((p, pv)) => v < p.num_vertices() && self.row_version[v] <= pv,
            None => false,
        };
        let mut v = lo;
        while v < hi {
            let dst_lo = offsets[v] as usize - base;
            if clean(v) {
                let mut w = v + 1;
                while w < hi && clean(w) {
                    w += 1;
                }
                let src = prev.unwrap().0.row_span(v as VertexIdx, w as VertexIdx);
                debug_assert_eq!(src.len() as u64, offsets[w] - offsets[v], "clean run desync");
                chunk[dst_lo..dst_lo + src.len()].copy_from_slice(src);
                v = w;
            } else {
                let row = &self.in_adj[v];
                chunk[dst_lo..dst_lo + row.len()].copy_from_slice(row);
                v += 1;
            }
        }
    }

    /// Iterate over all edges as (src_idx, dst_idx).
    pub fn edges(&self) -> impl Iterator<Item = (VertexIdx, VertexIdx)> + '_ {
        self.out_adj
            .iter()
            .enumerate()
            .flat_map(|(s, outs)| outs.iter().map(move |&d| (s as VertexIdx, d)))
    }

    /// All user ids in dense order.
    pub fn ids(&self) -> &[VertexId] {
        &self.id_of
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> DynamicGraph {
        let (g, dups) = DynamicGraph::from_edges(vec![(10, 20), (20, 30), (30, 10)]);
        assert_eq!(dups, 0);
        g
    }

    #[test]
    fn add_edges_and_degrees() {
        let g = triangle();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 3);
        let i10 = g.index(10).unwrap();
        assert_eq!(g.out_degree(i10), 1);
        assert_eq!(g.in_degree(i10), 1);
        assert_eq!(g.degree(i10), 2);
        assert!((g.mean_degree() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn duplicate_edge_rejected() {
        let mut g = triangle();
        assert!(g.add_edge(10, 20).is_err());
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn duplicate_count_in_bulk_load() {
        let (g, dups) = DynamicGraph::from_edges(vec![(1, 2), (1, 2), (2, 3)]);
        assert_eq!(dups, 1);
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn remove_edge_updates_both_sides() {
        let mut g = triangle();
        g.remove_edge(10, 20).unwrap();
        assert_eq!(g.num_edges(), 2);
        assert!(!g.has_edge(10, 20));
        let i20 = g.index(20).unwrap();
        assert_eq!(g.in_degree(i20), 0);
        assert!(g.remove_edge(10, 20).is_err());
        assert!(g.remove_edge(99, 20).is_err());
    }

    #[test]
    fn remove_vertex_clears_incident_edges() {
        let mut g = triangle();
        g.add_edge(20, 10).unwrap();
        g.remove_vertex(20).unwrap();
        assert_eq!(g.num_edges(), 1); // only 30 -> 10 survives
        assert!(!g.has_edge(10, 20) && !g.has_edge(20, 30) && !g.has_edge(20, 10));
        // slot survives: id still resolvable, isolated
        let i20 = g.index(20).unwrap();
        assert_eq!(g.degree(i20), 0);
    }

    #[test]
    fn self_loop_allowed_once() {
        let mut g = DynamicGraph::new();
        g.add_edge(5, 5).unwrap();
        assert!(g.add_edge(5, 5).is_err());
        let i = g.index(5).unwrap();
        assert_eq!(g.out_degree(i), 1);
        assert_eq!(g.in_degree(i), 1);
    }

    #[test]
    fn snapshot_matches_topology() {
        let g = triangle();
        let csr = g.snapshot();
        assert_eq!(csr.num_vertices(), 3);
        assert_eq!(csr.num_edges(), 3);
        for v in 0..3u32 {
            let srcs = csr.row(v);
            assert_eq!(srcs.len(), g.in_degree(v));
            for &s in srcs {
                assert!(g.out_neighbors(s).contains(&v));
            }
            assert_eq!(csr.out_degree(v) as usize, g.out_degree(v));
        }
    }

    #[test]
    fn version_bumps_on_every_successful_mutation_only() {
        let mut g = DynamicGraph::new();
        assert_eq!(g.version(), 0);
        g.add_vertex(1);
        let v1 = g.version();
        assert!(v1 > 0);
        g.add_vertex(1); // no-op: already present
        assert_eq!(g.version(), v1);
        g.add_edge(1, 2).unwrap(); // creates 2, adds edge
        let v2 = g.version();
        assert!(v2 > v1);
        assert!(g.add_edge(1, 2).is_err()); // duplicate: no bump
        assert_eq!(g.version(), v2);
        assert!(g.remove_edge(1, 9).is_err()); // unknown vertex: no bump
        assert!(g.remove_edge(2, 1).is_err()); // unknown edge: no bump
        assert_eq!(g.version(), v2);
        g.remove_edge(1, 2).unwrap();
        let v3 = g.version();
        assert!(v3 > v2);
        assert!(g.remove_vertex(9).is_err());
        assert_eq!(g.version(), v3);
        g.remove_vertex(2).unwrap();
        assert!(g.version() > v3);
    }

    #[test]
    fn parallel_snapshot_matches_serial() {
        let pool = ThreadPool::new(4);
        let mut g = triangle();
        g.add_vertex(99); // dangling + isolated row
        g.add_edge(10, 30).unwrap();
        let serial = g.snapshot();
        for shards in [1usize, 2, 3, 4, 7, 100] {
            assert_eq!(g.snapshot_with(Some(&pool), shards), serial, "shards={shards}");
        }
        // no pool ⇒ serial path regardless of the shard knob
        assert_eq!(g.snapshot_with(None, 8), serial);
        let empty = DynamicGraph::new();
        assert_eq!(empty.snapshot_with(Some(&pool), 4), empty.snapshot());
    }

    #[test]
    fn incremental_snapshot_matches_full_rebuild() {
        let pool = ThreadPool::new(4);
        let mut g = triangle();
        let base = g.snapshot();
        let v0 = g.version();
        // no mutations: incremental rebuild reproduces the base snapshot
        assert_eq!(g.snapshot_from(&base, v0, None, 1), base);
        // interleaved adds/removes, new vertices, a vertex removal
        g.add_edge(10, 30).unwrap();
        g.add_edge(40, 20).unwrap();
        g.remove_edge(20, 30).unwrap();
        g.add_vertex(50);
        g.remove_vertex(30).unwrap();
        let fresh = g.snapshot();
        assert_eq!(g.snapshot_from(&base, v0, None, 1), fresh);
        assert_eq!(g.snapshot_from(&base, v0, Some(&pool), 3), fresh);
        // chaining: incremental-of-incremental still matches
        let mid = g.snapshot_from(&base, v0, None, 1);
        let v1 = g.version();
        g.add_edge(50, 10).unwrap();
        assert_eq!(g.snapshot_from(&mid, v1, None, 1), g.snapshot());
    }

    #[test]
    fn ids_survive_in_dense_order() {
        let g = triangle();
        assert_eq!(g.ids(), &[10, 20, 30]);
        assert_eq!(g.id(g.index(30).unwrap()), 30);
    }

    #[test]
    fn edges_iterator_covers_all() {
        let g = triangle();
        let es: Vec<_> = g.edges().collect();
        assert_eq!(es.len(), 3);
    }
}
