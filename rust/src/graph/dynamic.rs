//! Mutable directed graph the update stream is applied to.
//!
//! Design notes:
//! * User-facing vertex ids are sparse `u64` (datasets keep their original
//!   ids); internally they compact to dense `u32` indices so CSR snapshots
//!   and rank vectors are flat arrays.
//! * Both out- and in-adjacency are maintained: PageRank pulls over
//!   in-edges, the hot-vertex expansion (Eqs. 3–4) walks neighborhoods in
//!   both directions, and degree deltas (Eq. 2) need out-degrees.
//! * Parallel edges are rejected (the paper's streams sample distinct
//!   edges); self-loops are allowed but excluded by the generators.
//! * Removal keeps the vertex slot (ids stay stable, as in the paper's
//!   model where a vertex's history matters across measurement points).

use std::collections::{HashMap, HashSet};

use crate::error::{Error, Result};
use crate::graph::csr::{balanced_cuts, Csr};
use crate::graph::{VertexId, VertexIdx};
use crate::stream::event::EdgeOp;
use crate::util::threadpool::ThreadPool;

/// Effective edge ops a segment needs before [`DynamicGraph::apply_batch`]
/// dispatches its grouped row merges over the pool — below this, scoped
/// dispatch costs more than the row work.
const BATCH_PARALLEL_MIN_OPS: usize = 1024;

/// Outcome of [`DynamicGraph::apply_batch`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BatchApply {
    /// Effective mutations performed (edge adds/removes, vertex
    /// inserts/removals).
    pub applied: usize,
    /// No-op operations (unknown-vertex removals; non-zero beyond that
    /// only when a conflicting input routed through the fallback).
    pub skipped: usize,
    /// Edges inserted.
    pub edges_added: usize,
    /// Edges deleted.
    pub edges_removed: usize,
    /// Vertex slots created (explicit `v+` plus edge endpoints).
    pub vertices_added: usize,
    /// True when the input was not a coalesced (conflict-free) op list
    /// and the sequential path replayed it instead.
    pub fallback: bool,
}

/// One row's grouped edge ops: targets to drop and targets to append, in
/// op order. `add_before_remove` records an ordering the grouped merge
/// cannot honor (an add of a pair that the same segment removes LATER —
/// the merge always removes first), set at grouping time since the two
/// lists alone lose the interleaving.
#[derive(Clone, Debug, Default)]
struct RowOps {
    adds: Vec<VertexIdx>,
    removes: Vec<VertexIdx>,
    add_before_remove: bool,
}

/// Per-row op count past which validation and merge switch from linear
/// scans to hashed membership — keeps remove-heavy rows (dismantling a
/// hub's fan-in) off the O(ops x degree) cliff.
const ROW_OPS_HASH_MIN: usize = 16;

/// A coalesced batch touches each (src, dst) pair at most as
/// {remove, then add}: removes must target present edges, adds absent
/// ones (unless the same segment removes them first), no duplicates
/// either way. Violations route the segment to the sequential fallback.
fn row_merge_valid(row: &[VertexIdx], rops: &RowOps) -> bool {
    if rops.add_before_remove {
        return false; // the merge would re-add an edge the raw order drops
    }
    if rops.removes.len() + rops.adds.len() >= ROW_OPS_HASH_MIN {
        return row_merge_valid_hashed(row, rops);
    }
    for (i, r) in rops.removes.iter().enumerate() {
        if rops.removes[..i].contains(r) || !row.contains(r) {
            return false;
        }
    }
    for (i, a) in rops.adds.iter().enumerate() {
        if rops.adds[..i].contains(a) {
            return false;
        }
        if row.contains(a) && !rops.removes.contains(a) {
            return false;
        }
    }
    true
}

/// [`row_merge_valid`] with hashed membership: O(ops + degree) instead
/// of O(ops x degree) for rows carrying many ops.
fn row_merge_valid_hashed(row: &[VertexIdx], rops: &RowOps) -> bool {
    let row_set: HashSet<VertexIdx> = row.iter().copied().collect();
    let mut removes = HashSet::with_capacity(rops.removes.len());
    for &r in &rops.removes {
        if !removes.insert(r) || !row_set.contains(&r) {
            return false;
        }
    }
    let mut adds = HashSet::with_capacity(rops.adds.len());
    for &a in &rops.adds {
        if !adds.insert(a) {
            return false;
        }
        if row_set.contains(&a) && !removes.contains(&a) {
            return false;
        }
    }
    true
}

/// One row's batched edit: order-preserving drop of the removed targets,
/// then append the adds in op order — bit-identical to applying the
/// row's ops one by one (removal is order-preserving, insertion appends).
fn merge_row(row: &mut Vec<VertexIdx>, rops: &RowOps) {
    if rops.removes.len() >= ROW_OPS_HASH_MIN {
        let removes: HashSet<VertexIdx> = rops.removes.iter().copied().collect();
        row.retain(|x| !removes.contains(x));
    } else if !rops.removes.is_empty() {
        row.retain(|x| !rops.removes.contains(x));
    }
    row.extend_from_slice(&rops.adds);
}

/// Apply grouped row edits, one mutation per touched row. Rows are
/// disjoint, so large batches shard over the pool: op-count-balanced cuts
/// over the touched-row list, mapped to slice cuts over the adjacency
/// table (every shard owns a contiguous row range).
fn merge_rows(
    adj: &mut [Vec<VertexIdx>],
    rows: &[(VertexIdx, RowOps)],
    pool: Option<&ThreadPool>,
    shards: usize,
) {
    let k = shards.clamp(1, rows.len().max(1));
    match pool {
        Some(pool) if k > 1 && !rows.is_empty() => {
            let row_cuts = balanced_cuts(rows.len(), k, |i| {
                (rows[i].1.adds.len() + rows[i].1.removes.len()) as u64
            });
            let mut cuts = Vec::with_capacity(row_cuts.len());
            for (j, &rc) in row_cuts.iter().enumerate() {
                cuts.push(if j == 0 {
                    0
                } else if rc == rows.len() {
                    adj.len()
                } else {
                    rows[rc].0 as usize
                });
            }
            pool.scope_chunks(adj, &cuts, |i, chunk| {
                let lo = cuts[i];
                for (r, rops) in &rows[row_cuts[i]..row_cuts[i + 1]] {
                    merge_row(&mut chunk[*r as usize - lo], rops);
                }
            });
        }
        _ => {
            for (r, rops) in rows {
                merge_row(&mut adj[*r as usize], rops);
            }
        }
    }
}

/// A growable directed graph with stable dense indices.
#[derive(Clone, Debug, Default)]
pub struct DynamicGraph {
    /// Sparse user id → dense index.
    index_of: HashMap<VertexId, VertexIdx>,
    /// Dense index → sparse user id.
    id_of: Vec<VertexId>,
    /// Out-adjacency per dense index.
    out_adj: Vec<Vec<VertexIdx>>,
    /// In-adjacency per dense index.
    in_adj: Vec<Vec<VertexIdx>>,
    /// Edge count.
    m: usize,
    /// Topology version: bumped on every successful mutation (vertex
    /// insert, edge add/remove, vertex removal). Failed or no-op calls
    /// (duplicate edge, `add_vertex` of an existing id, unknown-edge
    /// removal) leave it untouched. Snapshot caches key on this.
    version: u64,
    /// Per-row stamp: the version at which `in_adj[v]` last changed
    /// (vertex creation counts). Incremental snapshot builds compare a
    /// row's stamp against the cached snapshot's version to decide
    /// whether the old CSR row can be bulk-copied.
    row_version: Vec<u64>,
}

impl DynamicGraph {
    /// Empty graph.
    pub fn new() -> Self {
        Self::default()
    }

    /// Build from an iterator of (src, dst) pairs, adding vertices on the
    /// fly and ignoring duplicate edges (returns how many were ignored).
    pub fn from_edges<I: IntoIterator<Item = (VertexId, VertexId)>>(edges: I) -> (Self, usize) {
        let mut g = Self::new();
        let mut dups = 0;
        for (u, v) in edges {
            if g.add_edge(u, v).is_err() {
                dups += 1;
            }
        }
        (g, dups)
    }

    /// Number of vertices (including isolated ones).
    pub fn num_vertices(&self) -> usize {
        self.id_of.len()
    }

    /// Number of edges.
    pub fn num_edges(&self) -> usize {
        self.m
    }

    /// Current topology version (0 for an empty graph; see the field
    /// docs for the bump rules).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Force the topology version forward (recovery: a graph
    /// reconstructed from a checkpoint must report the version the
    /// checkpoint captured, not the mutation count of the rebuild).
    /// Only ever raises — row stamps written during reconstruction stay
    /// ≤ the version, keeping incremental snapshot builds correct.
    pub fn set_version(&mut self, v: u64) {
        if v > self.version {
            self.version = v;
        }
    }

    /// Dense index for a user id, if present.
    pub fn index(&self, id: VertexId) -> Option<VertexIdx> {
        self.index_of.get(&id).copied()
    }

    /// User id for a dense index.
    pub fn id(&self, idx: VertexIdx) -> VertexId {
        self.id_of[idx as usize]
    }

    /// Insert a vertex (no-op if present); returns its dense index.
    pub fn add_vertex(&mut self, id: VertexId) -> VertexIdx {
        if let Some(&i) = self.index_of.get(&id) {
            return i;
        }
        let idx = self.id_of.len() as VertexIdx;
        self.index_of.insert(id, idx);
        self.id_of.push(id);
        self.out_adj.push(Vec::new());
        self.in_adj.push(Vec::new());
        self.version += 1;
        self.row_version.push(self.version);
        idx
    }

    /// Add a directed edge; vertices are created as needed.
    ///
    /// Errors with [`Error::Parse`] on duplicate edges (the caller decides
    /// whether duplicates are benign — `from_edges` counts and drops them).
    pub fn add_edge(&mut self, src: VertexId, dst: VertexId) -> Result<()> {
        let s = self.add_vertex(src);
        let d = self.add_vertex(dst);
        if self.out_adj[s as usize].contains(&d) {
            return Err(Error::Parse(format!("duplicate edge ({src}, {dst})")));
        }
        self.out_adj[s as usize].push(d);
        self.in_adj[d as usize].push(s);
        self.m += 1;
        self.version += 1;
        self.row_version[d as usize] = self.version;
        Ok(())
    }

    /// Remove a directed edge. Order-preserving (`Vec::remove`, not
    /// `swap_remove`): batch coalescing relies on "surviving neighbors
    /// keep their relative order, net-new neighbors append" to replay a
    /// coalesced op list bit-identically to the raw op sequence.
    pub fn remove_edge(&mut self, src: VertexId, dst: VertexId) -> Result<()> {
        let s = self.index(src).ok_or(Error::UnknownVertex(src))?;
        let d = self.index(dst).ok_or(Error::UnknownVertex(dst))?;
        let out = &mut self.out_adj[s as usize];
        let pos = out.iter().position(|&x| x == d).ok_or(Error::UnknownEdge(src, dst))?;
        out.remove(pos);
        let inn = &mut self.in_adj[d as usize];
        let pos = inn.iter().position(|&x| x == s).expect("in/out adjacency desync");
        inn.remove(pos);
        self.m -= 1;
        self.version += 1;
        self.row_version[d as usize] = self.version;
        Ok(())
    }

    /// Remove a vertex and all incident edges. The dense slot survives
    /// (ids remain stable) but becomes isolated.
    pub fn remove_vertex(&mut self, id: VertexId) -> Result<()> {
        let v = self.index(id).ok_or(Error::UnknownVertex(id))?;
        self.version += 1;
        let outs: Vec<VertexIdx> = self.out_adj[v as usize].clone();
        for d in outs {
            let inn = &mut self.in_adj[d as usize];
            if let Some(p) = inn.iter().position(|&x| x == v) {
                inn.swap_remove(p);
                self.m -= 1;
                self.row_version[d as usize] = self.version;
            }
        }
        self.out_adj[v as usize].clear();
        let ins: Vec<VertexIdx> = self.in_adj[v as usize].clone();
        for s in ins {
            let out = &mut self.out_adj[s as usize];
            if let Some(p) = out.iter().position(|&x| x == v) {
                out.swap_remove(p);
                self.m -= 1;
            }
        }
        self.in_adj[v as usize].clear();
        self.row_version[v as usize] = self.version;
        Ok(())
    }

    /// Apply a batch of *effective* operations (the output of
    /// [`crate::stream::buffer::UpdateBuffer::take_batch`]) — the write
    /// path's grouped twin of op-by-op `add_edge`/`remove_edge`.
    ///
    /// Ops are grouped by row so every touched adjacency row is mutated
    /// exactly once, and the whole segment pays **one** topology version
    /// bump plus one per-row stamp pass (op-by-op pays one bump per op).
    /// Large segments shard the row merges over `pool`. The final graph
    /// state is bit-identical to applying `ops` sequentially.
    ///
    /// `RemoveVertex` ops are sequence points: the edge runs around them
    /// are batch-applied, the removals themselves run through
    /// [`Self::remove_vertex`] (with its own version bump).
    ///
    /// Inputs that are not conflict-free (duplicate pairs, adds of
    /// present edges, removes of absent ones) are detected before any
    /// row is mutated and replayed through the sequential path instead
    /// (`fallback` is set; counts still come out right).
    pub fn apply_batch(
        &mut self,
        ops: &[EdgeOp],
        pool: Option<&ThreadPool>,
        shards: usize,
    ) -> BatchApply {
        let mut out = BatchApply::default();
        let mut seg = 0usize;
        for (i, op) in ops.iter().enumerate() {
            if let EdgeOp::RemoveVertex(u) = *op {
                self.apply_edge_segment(&ops[seg..i], pool, shards, &mut out);
                if self.remove_vertex(u).is_ok() {
                    out.applied += 1;
                } else {
                    out.skipped += 1;
                }
                seg = i + 1;
            }
        }
        self.apply_edge_segment(&ops[seg..], pool, shards, &mut out);
        out
    }

    /// Apply one vertex-removal-free run of a batch: create vertices in
    /// first-mention order, group edge ops by row, validate, then merge
    /// every touched row once under a single version bump.
    fn apply_edge_segment(
        &mut self,
        ops: &[EdgeOp],
        pool: Option<&ThreadPool>,
        shards: usize,
        out: &mut BatchApply,
    ) {
        if ops.is_empty() {
            return;
        }
        // Pass 0: vertex creation in first-mention order (mirrors
        // `add_edge`/`add_vertex` creating on first sight) and dense
        // index resolution. No version bumps yet.
        let mut created: Vec<VertexIdx> = Vec::new();
        let mut resolved: Vec<(VertexIdx, VertexIdx, bool)> = Vec::with_capacity(ops.len());
        let mut unknown_removes = 0usize;
        for op in ops {
            match *op {
                EdgeOp::AddVertex(u) => {
                    let before = created.len();
                    self.ensure_vertex(u, &mut created);
                    if created.len() > before {
                        out.applied += 1;
                    } else {
                        out.skipped += 1;
                    }
                }
                EdgeOp::AddEdge(u, v) => {
                    let s = self.ensure_vertex(u, &mut created);
                    let d = self.ensure_vertex(v, &mut created);
                    resolved.push((s, d, true));
                }
                EdgeOp::RemoveEdge(u, v) => match (self.index(u), self.index(v)) {
                    (Some(s), Some(d)) => resolved.push((s, d, false)),
                    _ => unknown_removes += 1,
                },
                EdgeOp::RemoveVertex(_) => unreachable!("segments split at vertex removals"),
            }
        }
        out.vertices_added += created.len();

        // Group by row, preserving op order within each row.
        let mut by_out: HashMap<VertexIdx, RowOps> = HashMap::new();
        let mut by_in: HashMap<VertexIdx, RowOps> = HashMap::new();
        // Pairs added so far in this segment — a remove AFTER an add of
        // the same pair is an order the grouped merge (removes first,
        // then appends) cannot reproduce, so it must route the row to
        // the sequential fallback. Hashed: O(ops), not O(ops x row-ops).
        let mut added_pairs: HashSet<(VertexIdx, VertexIdx)> = HashSet::new();
        for &(s, d, is_add) in &resolved {
            let o = by_out.entry(s).or_default();
            if is_add {
                o.adds.push(d);
                added_pairs.insert((s, d));
            } else {
                if added_pairs.contains(&(s, d)) {
                    o.add_before_remove = true;
                }
                o.removes.push(d);
            }
            let i = by_in.entry(d).or_default();
            if is_add {
                i.adds.push(s);
            } else {
                i.removes.push(s);
            }
        }
        let mut out_rows: Vec<(VertexIdx, RowOps)> = by_out.into_iter().collect();
        out_rows.sort_unstable_by_key(|&(r, _)| r);
        let mut in_rows: Vec<(VertexIdx, RowOps)> = by_in.into_iter().collect();
        in_rows.sort_unstable_by_key(|&(r, _)| r);

        // Validate on the out side only — the in side mirrors it through
        // the adjacency invariant.
        let valid =
            out_rows.iter().all(|(s, rops)| row_merge_valid(&self.out_adj[*s as usize], rops));
        if !valid {
            if !created.is_empty() {
                self.version += 1;
                let ver = self.version;
                for &c in &created {
                    self.row_version[c as usize] = ver;
                }
            }
            for op in ops {
                match *op {
                    EdgeOp::AddEdge(u, v) => {
                        if self.add_edge(u, v).is_ok() {
                            out.applied += 1;
                            out.edges_added += 1;
                        } else {
                            out.skipped += 1;
                        }
                    }
                    EdgeOp::RemoveEdge(u, v) => {
                        if self.remove_edge(u, v).is_ok() {
                            out.applied += 1;
                            out.edges_removed += 1;
                        } else {
                            out.skipped += 1;
                        }
                    }
                    _ => {} // vertex inserts were handled (and counted) above
                }
            }
            out.fallback = true;
            return;
        }

        out.skipped += unknown_removes;
        let adds: usize = out_rows.iter().map(|(_, r)| r.adds.len()).sum();
        let removes: usize = out_rows.iter().map(|(_, r)| r.removes.len()).sum();
        if adds + removes == 0 && created.is_empty() {
            return;
        }

        // One topology version bump for the whole segment.
        self.version += 1;
        let ver = self.version;
        let shards = if adds + removes >= BATCH_PARALLEL_MIN_OPS { shards } else { 1 };
        merge_rows(&mut self.out_adj, &out_rows, pool, shards);
        merge_rows(&mut self.in_adj, &in_rows, pool, shards);
        // Stamp pass: rows whose in-adjacency changed, plus created rows.
        for &(d, _) in &in_rows {
            self.row_version[d as usize] = ver;
        }
        for &c in &created {
            self.row_version[c as usize] = ver;
        }
        // Add before subtracting: `removes` alone may exceed `m - adds`.
        self.m = self.m + adds - removes;
        out.applied += adds + removes;
        out.edges_added += adds;
        out.edges_removed += removes;
    }

    /// Insert a vertex without bumping the topology version — batch
    /// apply bumps once per segment and stamps created rows then.
    fn ensure_vertex(&mut self, id: VertexId, created: &mut Vec<VertexIdx>) -> VertexIdx {
        if let Some(&i) = self.index_of.get(&id) {
            return i;
        }
        let idx = self.id_of.len() as VertexIdx;
        self.index_of.insert(id, idx);
        self.id_of.push(id);
        self.out_adj.push(Vec::new());
        self.in_adj.push(Vec::new());
        self.row_version.push(0); // stamped at segment end
        created.push(idx);
        idx
    }

    /// True if the edge exists.
    pub fn has_edge(&self, src: VertexId, dst: VertexId) -> bool {
        match (self.index(src), self.index(dst)) {
            (Some(s), Some(d)) => self.out_adj[s as usize].contains(&d),
            _ => false,
        }
    }

    /// Out-degree by dense index.
    pub fn out_degree(&self, idx: VertexIdx) -> usize {
        self.out_adj[idx as usize].len()
    }

    /// In-degree by dense index.
    pub fn in_degree(&self, idx: VertexIdx) -> usize {
        self.in_adj[idx as usize].len()
    }

    /// Total degree (in + out) by dense index — the paper's `d_t(u)` uses
    /// the degree affected by incoming stream updates.
    pub fn degree(&self, idx: VertexIdx) -> usize {
        self.out_degree(idx) + self.in_degree(idx)
    }

    /// Out-neighbors by dense index.
    pub fn out_neighbors(&self, idx: VertexIdx) -> &[VertexIdx] {
        &self.out_adj[idx as usize]
    }

    /// In-neighbors by dense index.
    pub fn in_neighbors(&self, idx: VertexIdx) -> &[VertexIdx] {
        &self.in_adj[idx as usize]
    }

    /// Mean total degree over all vertices (`d̄` in Eq. 5).
    pub fn mean_degree(&self) -> f64 {
        if self.id_of.is_empty() {
            return 0.0;
        }
        // Every edge contributes one out- and one in-degree.
        2.0 * self.m as f64 / self.id_of.len() as f64
    }

    /// Freeze the current topology into a pull-oriented CSR snapshot:
    /// in-edge CSR plus out-degree array (what the power method consumes).
    /// CSR row `v` lists the *sources* of `v`'s in-edges. Serial full
    /// build; see [`Self::snapshot_with`] / [`Self::snapshot_from`] for
    /// the parallel and incremental variants (all three are bit-identical
    /// for the same topology).
    pub fn snapshot(&self) -> Csr {
        self.build_snapshot(None, None, 1)
    }

    /// Full snapshot rebuild, parallel when a pool is supplied and
    /// `shards > 1`: a two-pass build over `shards` in-degree-balanced
    /// row ranges (pass 1 computes per-range offset prefix sums, pass 2
    /// fills disjoint `targets` slices). Bit-identical to
    /// [`Self::snapshot`] for every shard count.
    pub fn snapshot_with(&self, pool: Option<&ThreadPool>, shards: usize) -> Csr {
        self.build_snapshot(None, pool, shards)
    }

    /// Incremental snapshot rebuild: rows untouched since `prev_version`
    /// are bulk-copied from `prev` (runs of clean rows collapse into one
    /// `copy_from_slice`); dirty rows re-read the live adjacency. Offsets
    /// and out-degrees are always rebuilt (O(n) — cheap next to the edge
    /// fill). Contract: `prev` MUST be a snapshot THIS graph produced at
    /// version `prev_version` ([`crate::graph::snapshot::SnapshotCache`]
    /// enforces the pairing; diverged clones sharing version numbers
    /// would silently corrupt rows).
    pub fn snapshot_from(
        &self,
        prev: &Csr,
        prev_version: u64,
        pool: Option<&ThreadPool>,
        shards: usize,
    ) -> Csr {
        self.build_snapshot(Some((prev, prev_version)), pool, shards)
    }

    /// The one snapshot builder behind the three public variants.
    fn build_snapshot(
        &self,
        prev: Option<(&Csr, u64)>,
        pool: Option<&ThreadPool>,
        shards: usize,
    ) -> Csr {
        let n = self.num_vertices();
        let shards = shards.clamp(1, n.max(1));
        let mut offsets = vec![0u64; n + 1];
        let mut out_degree = vec![0u32; n];
        let mut targets = vec![0 as VertexIdx; self.m];
        match pool {
            Some(pool) if shards > 1 && n > 0 => {
                let cuts = balanced_cuts(n, shards, |v| self.in_adj[v].len() as u64);
                // Pass 1: per-range local prefix sums of in-degrees, then
                // rebase each range by the exclusive scan of range totals.
                let totals = pool.scope_chunks(&mut offsets[1..], &cuts, |i, chunk| {
                    let lo = cuts[i];
                    let mut run = 0u64;
                    for (off, slot) in chunk.iter_mut().enumerate() {
                        run += self.in_adj[lo + off].len() as u64;
                        *slot = run;
                    }
                    run
                });
                let mut bases = vec![0u64; cuts.len()];
                for (i, t) in totals.iter().enumerate() {
                    bases[i + 1] = bases[i] + t;
                }
                pool.scope_chunks(&mut offsets[1..], &cuts, |i, chunk| {
                    if bases[i] > 0 {
                        for slot in chunk.iter_mut() {
                            *slot += bases[i];
                        }
                    }
                });
                pool.scope_chunks(&mut out_degree, &cuts, |i, chunk| {
                    let lo = cuts[i];
                    for (off, slot) in chunk.iter_mut().enumerate() {
                        *slot = self.out_adj[lo + off].len() as u32;
                    }
                });
                // Pass 2: each range owns a disjoint targets slice (row
                // cuts mapped through the now-final offsets).
                let ecuts: Vec<usize> = cuts.iter().map(|&r| offsets[r] as usize).collect();
                let offsets_ref = &offsets;
                pool.scope_chunks(&mut targets, &ecuts, |i, chunk| {
                    self.fill_rows(chunk, cuts[i], cuts[i + 1], offsets_ref, prev);
                });
            }
            _ => {
                let mut run = 0u64;
                for v in 0..n {
                    run += self.in_adj[v].len() as u64;
                    offsets[v + 1] = run;
                    out_degree[v] = self.out_adj[v].len() as u32;
                }
                self.fill_rows(&mut targets, 0, n, &offsets, prev);
            }
        }
        Csr::from_parts(offsets, targets, out_degree)
    }

    /// Fill `chunk` — the targets slice for rows `lo..hi`, based at
    /// `offsets[lo]` — copying runs of unchanged rows from `prev` in bulk
    /// and re-reading dirty rows from the live adjacency.
    fn fill_rows(
        &self,
        chunk: &mut [VertexIdx],
        lo: usize,
        hi: usize,
        offsets: &[u64],
        prev: Option<(&Csr, u64)>,
    ) {
        let base = offsets[lo] as usize;
        let clean = |v: usize| match prev {
            Some((p, pv)) => v < p.num_vertices() && self.row_version[v] <= pv,
            None => false,
        };
        let mut v = lo;
        while v < hi {
            let dst_lo = offsets[v] as usize - base;
            if clean(v) {
                let mut w = v + 1;
                while w < hi && clean(w) {
                    w += 1;
                }
                let src = prev.unwrap().0.row_span(v as VertexIdx, w as VertexIdx);
                debug_assert_eq!(src.len() as u64, offsets[w] - offsets[v], "clean run desync");
                chunk[dst_lo..dst_lo + src.len()].copy_from_slice(src);
                v = w;
            } else {
                let row = &self.in_adj[v];
                chunk[dst_lo..dst_lo + row.len()].copy_from_slice(row);
                v += 1;
            }
        }
    }

    /// Iterate over all edges as (src_idx, dst_idx).
    pub fn edges(&self) -> impl Iterator<Item = (VertexIdx, VertexIdx)> + '_ {
        self.out_adj
            .iter()
            .enumerate()
            .flat_map(|(s, outs)| outs.iter().map(move |&d| (s as VertexIdx, d)))
    }

    /// All user ids in dense order.
    pub fn ids(&self) -> &[VertexId] {
        &self.id_of
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> DynamicGraph {
        let (g, dups) = DynamicGraph::from_edges(vec![(10, 20), (20, 30), (30, 10)]);
        assert_eq!(dups, 0);
        g
    }

    #[test]
    fn add_edges_and_degrees() {
        let g = triangle();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 3);
        let i10 = g.index(10).unwrap();
        assert_eq!(g.out_degree(i10), 1);
        assert_eq!(g.in_degree(i10), 1);
        assert_eq!(g.degree(i10), 2);
        assert!((g.mean_degree() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn duplicate_edge_rejected() {
        let mut g = triangle();
        assert!(g.add_edge(10, 20).is_err());
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn duplicate_count_in_bulk_load() {
        let (g, dups) = DynamicGraph::from_edges(vec![(1, 2), (1, 2), (2, 3)]);
        assert_eq!(dups, 1);
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn remove_edge_updates_both_sides() {
        let mut g = triangle();
        g.remove_edge(10, 20).unwrap();
        assert_eq!(g.num_edges(), 2);
        assert!(!g.has_edge(10, 20));
        let i20 = g.index(20).unwrap();
        assert_eq!(g.in_degree(i20), 0);
        assert!(g.remove_edge(10, 20).is_err());
        assert!(g.remove_edge(99, 20).is_err());
    }

    #[test]
    fn remove_vertex_clears_incident_edges() {
        let mut g = triangle();
        g.add_edge(20, 10).unwrap();
        g.remove_vertex(20).unwrap();
        assert_eq!(g.num_edges(), 1); // only 30 -> 10 survives
        assert!(!g.has_edge(10, 20) && !g.has_edge(20, 30) && !g.has_edge(20, 10));
        // slot survives: id still resolvable, isolated
        let i20 = g.index(20).unwrap();
        assert_eq!(g.degree(i20), 0);
    }

    #[test]
    fn self_loop_allowed_once() {
        let mut g = DynamicGraph::new();
        g.add_edge(5, 5).unwrap();
        assert!(g.add_edge(5, 5).is_err());
        let i = g.index(5).unwrap();
        assert_eq!(g.out_degree(i), 1);
        assert_eq!(g.in_degree(i), 1);
    }

    #[test]
    fn snapshot_matches_topology() {
        let g = triangle();
        let csr = g.snapshot();
        assert_eq!(csr.num_vertices(), 3);
        assert_eq!(csr.num_edges(), 3);
        for v in 0..3u32 {
            let srcs = csr.row(v);
            assert_eq!(srcs.len(), g.in_degree(v));
            for &s in srcs {
                assert!(g.out_neighbors(s).contains(&v));
            }
            assert_eq!(csr.out_degree(v) as usize, g.out_degree(v));
        }
    }

    #[test]
    fn version_bumps_on_every_successful_mutation_only() {
        let mut g = DynamicGraph::new();
        assert_eq!(g.version(), 0);
        g.add_vertex(1);
        let v1 = g.version();
        assert!(v1 > 0);
        g.add_vertex(1); // no-op: already present
        assert_eq!(g.version(), v1);
        g.add_edge(1, 2).unwrap(); // creates 2, adds edge
        let v2 = g.version();
        assert!(v2 > v1);
        assert!(g.add_edge(1, 2).is_err()); // duplicate: no bump
        assert_eq!(g.version(), v2);
        assert!(g.remove_edge(1, 9).is_err()); // unknown vertex: no bump
        assert!(g.remove_edge(2, 1).is_err()); // unknown edge: no bump
        assert_eq!(g.version(), v2);
        g.remove_edge(1, 2).unwrap();
        let v3 = g.version();
        assert!(v3 > v2);
        assert!(g.remove_vertex(9).is_err());
        assert_eq!(g.version(), v3);
        g.remove_vertex(2).unwrap();
        assert!(g.version() > v3);
    }

    #[test]
    fn parallel_snapshot_matches_serial() {
        let pool = ThreadPool::new(4);
        let mut g = triangle();
        g.add_vertex(99); // dangling + isolated row
        g.add_edge(10, 30).unwrap();
        let serial = g.snapshot();
        for shards in [1usize, 2, 3, 4, 7, 100] {
            assert_eq!(g.snapshot_with(Some(&pool), shards), serial, "shards={shards}");
        }
        // no pool ⇒ serial path regardless of the shard knob
        assert_eq!(g.snapshot_with(None, 8), serial);
        let empty = DynamicGraph::new();
        assert_eq!(empty.snapshot_with(Some(&pool), 4), empty.snapshot());
    }

    #[test]
    fn incremental_snapshot_matches_full_rebuild() {
        let pool = ThreadPool::new(4);
        let mut g = triangle();
        let base = g.snapshot();
        let v0 = g.version();
        // no mutations: incremental rebuild reproduces the base snapshot
        assert_eq!(g.snapshot_from(&base, v0, None, 1), base);
        // interleaved adds/removes, new vertices, a vertex removal
        g.add_edge(10, 30).unwrap();
        g.add_edge(40, 20).unwrap();
        g.remove_edge(20, 30).unwrap();
        g.add_vertex(50);
        g.remove_vertex(30).unwrap();
        let fresh = g.snapshot();
        assert_eq!(g.snapshot_from(&base, v0, None, 1), fresh);
        assert_eq!(g.snapshot_from(&base, v0, Some(&pool), 3), fresh);
        // chaining: incremental-of-incremental still matches
        let mid = g.snapshot_from(&base, v0, None, 1);
        let v1 = g.version();
        g.add_edge(50, 10).unwrap();
        assert_eq!(g.snapshot_from(&mid, v1, None, 1), g.snapshot());
    }

    #[test]
    fn ids_survive_in_dense_order() {
        let g = triangle();
        assert_eq!(g.ids(), &[10, 20, 30]);
        assert_eq!(g.id(g.index(30).unwrap()), 30);
    }

    #[test]
    fn edges_iterator_covers_all() {
        let g = triangle();
        let es: Vec<_> = g.edges().collect();
        assert_eq!(es.len(), 3);
    }

    /// Sequentially apply ops through the public per-op API (the shared
    /// oracle `apply_batch` must match bit-for-bit).
    fn seq_apply(g: &mut DynamicGraph, ops: &[EdgeOp]) {
        let _ = crate::testing::oracle::seq_apply(g, ops);
    }

    #[test]
    fn apply_batch_matches_sequential_and_bumps_once() {
        let mut a = triangle();
        let mut b = a.clone();
        let v0 = a.version();
        // An effective (conflict-free) op list: new vertices, appends, a
        // removal, a re-establishment.
        let ops = vec![
            EdgeOp::AddVertex(77),
            EdgeOp::remove(10, 20),
            EdgeOp::add(40, 10),
            EdgeOp::remove(20, 30),
            EdgeOp::add(20, 30), // re-establish: moves to the append slot
            EdgeOp::add(77, 40),
        ];
        let res = a.apply_batch(&ops, None, 1);
        seq_apply(&mut b, &ops);
        assert_eq!(a.ids(), b.ids());
        assert_eq!(a.snapshot(), b.snapshot());
        assert!(!res.fallback);
        assert_eq!(res.applied, 6);
        assert_eq!((res.edges_added, res.edges_removed, res.vertices_added), (3, 2, 2));
        assert_eq!(a.version(), v0 + 1, "one bump per pure-edge batch");
    }

    #[test]
    fn apply_batch_incremental_snapshot_stays_correct() {
        // The single stamp pass must keep `snapshot_from` exact: rows the
        // batch left untouched bulk-copy, touched rows rebuild.
        let mut g = triangle();
        let base = g.snapshot();
        let v0 = g.version();
        let ops = vec![
            EdgeOp::add(10, 30),
            EdgeOp::remove(20, 30),
            EdgeOp::add(50, 20),
            EdgeOp::AddVertex(60),
        ];
        g.apply_batch(&ops, None, 1);
        assert_eq!(g.snapshot_from(&base, v0, None, 1), g.snapshot());
    }

    #[test]
    fn apply_batch_conflicting_input_falls_back() {
        let mut a = triangle();
        let mut b = a.clone();
        // Duplicate add + remove-of-absent: not a coalesced list.
        let ops = vec![EdgeOp::add(10, 20), EdgeOp::remove(10, 99), EdgeOp::add(10, 30)];
        let res = a.apply_batch(&ops, None, 1);
        seq_apply(&mut b, &ops);
        assert!(res.fallback);
        assert_eq!(res.applied, 1, "only add(10,30) lands");
        assert_eq!(res.skipped, 2);
        assert_eq!(a.snapshot(), b.snapshot());
    }

    #[test]
    fn apply_batch_add_then_remove_of_present_edge_falls_back() {
        // Raw order: duplicate add (skipped), then remove — the edge ends
        // ABSENT. The grouped merge would remove-then-re-append it, so
        // this ordering must route to the sequential fallback.
        let mut a = triangle();
        let mut b = a.clone();
        let ops = vec![EdgeOp::add(10, 20), EdgeOp::remove(10, 20)];
        let res = a.apply_batch(&ops, None, 1);
        seq_apply(&mut b, &ops);
        assert!(res.fallback);
        assert!(!a.has_edge(10, 20), "raw order drops the edge");
        assert_eq!(a.num_edges(), 2);
        assert_eq!(a.snapshot(), b.snapshot());
        assert_eq!((res.applied, res.skipped), (1, 1));
    }

    #[test]
    fn apply_batch_empty_and_noop_inputs_leave_version_alone() {
        let mut g = triangle();
        let v0 = g.version();
        assert_eq!(g.apply_batch(&[], None, 1), BatchApply::default());
        // Unknown-vertex removals are skipped without a bump.
        let res = g.apply_batch(&[EdgeOp::remove(98, 99), EdgeOp::RemoveVertex(98)], None, 1);
        assert_eq!((res.applied, res.skipped), (0, 2));
        assert_eq!(g.version(), v0);
    }

    #[test]
    fn apply_batch_vertex_removal_is_a_sequence_point() {
        let mut a = triangle();
        let mut b = a.clone();
        let ops = vec![
            EdgeOp::add(10, 30),
            EdgeOp::RemoveVertex(20),
            EdgeOp::add(20, 10), // slot survives, edge re-attaches
        ];
        a.apply_batch(&ops, None, 1);
        seq_apply(&mut b, &ops);
        assert_eq!(a.snapshot(), b.snapshot());
        assert!(a.has_edge(20, 10) && !a.has_edge(20, 30) && !a.has_edge(10, 20));
    }

    #[test]
    fn apply_batch_hashed_row_merge_matches_sequential() {
        // A hub losing many out-edges (hashed validation: the out row
        // carries 40 ops) and many in-edges (hashed retain on the in
        // row) at once — both sides cross ROW_OPS_HASH_MIN.
        let hub = 9_999u64;
        let (base, _) = DynamicGraph::from_edges(
            (0..80u64).map(|i| (i, hub)).chain((0..80u64).map(|i| (hub, 1_000 + i))),
        );
        let mut ops: Vec<EdgeOp> = (0..40u64).map(|i| EdgeOp::remove(i * 2, hub)).collect();
        ops.extend((0..40u64).map(|i| EdgeOp::remove(hub, 1_000 + i * 2)));
        ops.push(EdgeOp::add(hub, 0));
        let mut a = base.clone();
        let mut b = base.clone();
        let res = a.apply_batch(&ops, None, 1);
        seq_apply(&mut b, &ops);
        assert!(!res.fallback);
        assert_eq!((res.edges_added, res.edges_removed), (1, 80));
        assert_eq!(a.snapshot(), b.snapshot());
        // Hashed validation still rejects conflicts (a duplicate remove
        // buried in a 21-op row).
        let mut dup: Vec<EdgeOp> = (0..20u64).map(|i| EdgeOp::remove(hub, 1_000 + i)).collect();
        dup.push(EdgeOp::remove(hub, 1_000));
        let mut c = base.clone();
        let mut d = base.clone();
        let res = c.apply_batch(&dup, None, 1);
        seq_apply(&mut d, &dup);
        assert!(res.fallback);
        assert_eq!(c.snapshot(), d.snapshot());
    }

    #[test]
    fn apply_batch_parallel_matches_serial() {
        let pool = ThreadPool::new(4);
        // A batch big enough to cross the parallel threshold: a fresh
        // star plus removals against a pre-built graph.
        let (base, _) = DynamicGraph::from_edges((0..600u64).map(|i| (i, (i + 1) % 600)));
        let mut ops: Vec<EdgeOp> = (0..BATCH_PARALLEL_MIN_OPS as u64)
            .map(|i| EdgeOp::add(1_000 + i, i % 600))
            .collect();
        for i in 0..200u64 {
            ops.push(EdgeOp::remove(i * 3 % 600, (i * 3 + 1) % 600));
        }
        let mut serial = base.clone();
        let rs = serial.apply_batch(&ops, None, 1);
        for shards in [2usize, 4, 7] {
            let mut par = base.clone();
            let rp = par.apply_batch(&ops, Some(&pool), shards);
            assert_eq!(rp, rs, "shards={shards}");
            assert_eq!(par.snapshot(), serial.snapshot(), "shards={shards}");
            assert_eq!(par.version(), serial.version(), "shards={shards}");
        }
    }
}
