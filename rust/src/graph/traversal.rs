//! Breadth-first neighborhood expansion.
//!
//! The paper's `K_n` (Eq. 3) expands a uniform diameter `n` around the
//! seed set `K_r`; `K_Δ` (Eq. 4) expands a *per-vertex* radius `f_Δ(v)`.
//! Both reduce to a multi-source BFS with per-frontier-vertex depth
//! budgets, implemented here over the [`DynamicGraph`] adjacency (both
//! edge directions — update locality propagates along either).
//!
//! Each walk has two implementations sharing one semantics: the original
//! queue-based serial loop ([`bfs_multi`]/[`bfs_budgeted`]) and a
//! level-synchronous pooled twin ([`bfs_multi_pooled`]/
//! [`bfs_budgeted_pooled`]) that shards each frontier across the
//! engine's [`ThreadPool`] and reuses a caller-owned [`BfsScratch`]
//! instead of allocating O(|V|) visit state per call. The pooled twins
//! reach exactly the serial vertex set at exactly the serial depths for
//! every shard count: level barriers make the claimed *set* per level
//! schedule-independent, and a per-level sort makes the *order* so.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU32, Ordering};

use crate::graph::csr::balanced_cuts;
use crate::graph::dynamic::DynamicGraph;
use crate::graph::VertexIdx;
use crate::util::threadpool::ThreadPool;

/// Below this frontier size a level is expanded inline — dispatch
/// overhead would swamp the per-vertex work.
const MIN_PARALLEL_FRONTIER: usize = 256;

/// Which adjacency to walk during expansion.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    Out,
    In,
    Both,
}

fn push_neighbors(
    g: &DynamicGraph,
    v: VertexIdx,
    dir: Direction,
    mut f: impl FnMut(VertexIdx),
) {
    if matches!(dir, Direction::Out | Direction::Both) {
        for &w in g.out_neighbors(v) {
            f(w);
        }
    }
    if matches!(dir, Direction::In | Direction::Both) {
        for &w in g.in_neighbors(v) {
            f(w);
        }
    }
}

/// Multi-source BFS up to `max_depth` hops; returns `(vertex, depth)` for
/// every vertex reached (seeds at depth 0, each vertex reported once at
/// its minimum depth).
pub fn bfs_multi(
    g: &DynamicGraph,
    seeds: &[VertexIdx],
    max_depth: u32,
    dir: Direction,
) -> Vec<(VertexIdx, u32)> {
    let n = g.num_vertices();
    let mut depth = vec![u32::MAX; n];
    let mut out = Vec::new();
    let mut q = VecDeque::new();
    for &s in seeds {
        if depth[s as usize] == u32::MAX {
            depth[s as usize] = 0;
            out.push((s, 0));
            q.push_back(s);
        }
    }
    while let Some(v) = q.pop_front() {
        let d = depth[v as usize];
        if d >= max_depth {
            continue;
        }
        push_neighbors(g, v, dir, |w| {
            if depth[w as usize] == u32::MAX {
                depth[w as usize] = d + 1;
                out.push((w, d + 1));
                q.push_back(w);
            }
        });
    }
    out
}

/// BFS where each seed carries its own depth budget (the `K_Δ` shape):
/// vertex `w` is reached if some seed `s` with budget `b_s` satisfies
/// `dist(s, w) <= b_s`. Implemented as a best-budget propagation: the
/// frontier carries the *remaining* budget, and a vertex is re-expanded
/// only if reached with a strictly larger remaining budget.
pub fn bfs_budgeted(
    g: &DynamicGraph,
    seeds: &[(VertexIdx, u32)],
    dir: Direction,
) -> Vec<VertexIdx> {
    let n = g.num_vertices();
    // remaining[v] = best remaining budget when v was reached (+1 offset; 0
    // = unreached).
    let mut remaining = vec![0u32; n];
    let mut q = VecDeque::new();
    for &(s, b) in seeds {
        let r = b.saturating_add(1);
        if r > remaining[s as usize] {
            remaining[s as usize] = r;
            q.push_back(s);
        }
    }
    let mut out: Vec<VertexIdx> = Vec::new();
    while let Some(v) = q.pop_front() {
        let r = remaining[v as usize];
        if r <= 1 {
            continue; // no budget left to expand
        }
        push_neighbors(g, v, dir, |w| {
            if r - 1 > remaining[w as usize] {
                remaining[w as usize] = r - 1;
                q.push_back(w);
            }
        });
    }
    for v in 0..n {
        if remaining[v] > 0 {
            out.push(v as VertexIdx);
        }
    }
    out
}

/// Reusable visit state for the pooled BFS twins.
///
/// `depth[v] == u32::MAX` ⇔ unreached ([`bfs_multi_pooled`]);
/// `remaining[v] == 0` ⇔ untouched ([`bfs_budgeted_pooled`]). Both
/// arrays are restored by a *dirty-list* walk over the (small) reached
/// set when a traversal returns, so a recycled scratch costs O(|reached|)
/// per call instead of an O(|V|) allocation + clear.
#[derive(Debug, Default)]
pub struct BfsScratch {
    depth: Vec<AtomicU32>,
    remaining: Vec<AtomicU32>,
}

impl BfsScratch {
    /// Empty scratch; buffers grow on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow both arrays to cover `n` vertices (never shrinks); returns
    /// whether any allocation happened.
    pub fn ensure(&mut self, n: usize) -> bool {
        let grew = self.depth.len() < n;
        if grew {
            self.depth.resize_with(n, || AtomicU32::new(u32::MAX));
            self.remaining.resize_with(n, || AtomicU32::new(0));
        }
        grew
    }
}

/// How many neighbors `v` exposes in direction `dir` (shard weight for
/// frontier balancing).
fn neighbor_count(g: &DynamicGraph, v: VertexIdx, dir: Direction) -> usize {
    match dir {
        Direction::Out => g.out_degree(v),
        Direction::In => g.in_degree(v),
        Direction::Both => g.degree(v),
    }
}

/// Degree-balanced cut points over a frontier (the expansion work per
/// frontier vertex is its neighbor count, not 1).
fn frontier_cuts(g: &DynamicGraph, front: &[VertexIdx], dir: Direction, k: usize) -> Vec<usize> {
    balanced_cuts(front.len(), k, |i| neighbor_count(g, front[i], dir) as u64)
}

/// Claim every unreached neighbor of `frontier` at depth `d`, returning
/// the new frontier sorted by vertex index. Claims go through a CAS on
/// the shared depth array: the level barrier makes the claimed set
/// schedule-independent (a vertex is claimed at level `d` iff it was
/// unreached after level `d - 1` and is adjacent to the frontier), and
/// the sort fixes the order. Relaxed ordering suffices — CAS uniqueness
/// does not need fences, and `scope_chunks` joins before any read.
fn expand_level(
    g: &DynamicGraph,
    frontier: &[VertexIdx],
    dir: Direction,
    d: u32,
    depth: &[AtomicU32],
    pool: Option<&ThreadPool>,
    shards: usize,
) -> Vec<VertexIdx> {
    let claim = |v: VertexIdx, out: &mut Vec<VertexIdx>| {
        if depth[v as usize]
            .compare_exchange(u32::MAX, d, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
        {
            out.push(v);
        }
    };
    let fshards = frontier.len().div_ceil(MIN_PARALLEL_FRONTIER).clamp(1, shards.max(1));
    let mut next = match pool {
        Some(pool) if fshards > 1 => {
            let cuts = frontier_cuts(g, frontier, dir, fshards);
            let slots = pool.scope_slots(fshards, |i| {
                let mut local = Vec::new();
                for &v in &frontier[cuts[i]..cuts[i + 1]] {
                    push_neighbors(g, v, dir, |w| claim(w, &mut local));
                }
                local
            });
            slots.concat()
        }
        _ => {
            let mut local = Vec::new();
            for &v in frontier {
                push_neighbors(g, v, dir, |w| claim(w, &mut local));
            }
            local
        }
    };
    next.sort_unstable();
    next
}

/// Frontier-parallel twin of [`bfs_multi`]: level-synchronous expansion
/// over `shards` degree-balanced frontier cuts dispatched on `pool`
/// (inline when the pool is absent or a frontier is small). Reaches the
/// identical `(vertex, depth)` set as the serial walk for every shard
/// count; vertices are reported grouped by depth — seeds first (input
/// order, duplicates dropped), then each level ascending by index — so
/// the output is deterministic and shard-count-independent. Visit state
/// lives in `scratch` and is dirty-reset before returning.
pub fn bfs_multi_pooled(
    g: &DynamicGraph,
    seeds: &[VertexIdx],
    max_depth: u32,
    dir: Direction,
    scratch: &mut BfsScratch,
    pool: Option<&ThreadPool>,
    shards: usize,
) -> Vec<(VertexIdx, u32)> {
    scratch.ensure(g.num_vertices());
    let depth = &scratch.depth;
    let mut out: Vec<(VertexIdx, u32)> = Vec::new();
    let mut frontier: Vec<VertexIdx> = Vec::new();
    for &s in seeds {
        if depth[s as usize].swap(0, Ordering::Relaxed) == u32::MAX {
            out.push((s, 0));
            frontier.push(s);
        }
    }
    let mut d = 0u32;
    while !frontier.is_empty() && d < max_depth {
        let next = expand_level(g, &frontier, dir, d + 1, depth, pool, shards);
        for &w in &next {
            out.push((w, d + 1));
        }
        frontier = next;
        d += 1;
    }
    for &(v, _) in &out {
        depth[v as usize].store(u32::MAX, Ordering::Relaxed);
    }
    out
}

/// One budget-relaxation round: every frontier vertex re-reads its
/// (possibly just-improved) remaining budget and `fetch_max`es `r - 1`
/// into each neighbor. Returns `(improved, newly_touched)`: vertices
/// whose budget rose this round (sorted + deduped — the next frontier)
/// and vertices touched for the first time (`old == 0`, claimed exactly
/// once globally by atomicity).
fn relax_level(
    g: &DynamicGraph,
    frontier: &[VertexIdx],
    dir: Direction,
    remaining: &[AtomicU32],
    pool: Option<&ThreadPool>,
    shards: usize,
) -> (Vec<VertexIdx>, Vec<VertexIdx>) {
    let relax = |v: VertexIdx, improved: &mut Vec<VertexIdx>, newly: &mut Vec<VertexIdx>| {
        let r = remaining[v as usize].load(Ordering::Relaxed);
        if r <= 1 {
            return; // no budget left to expand
        }
        push_neighbors(g, v, dir, |w| {
            let old = remaining[w as usize].fetch_max(r - 1, Ordering::Relaxed);
            if old == 0 {
                newly.push(w);
            }
            if old < r - 1 {
                improved.push(w);
            }
        });
    };
    let fshards = frontier.len().div_ceil(MIN_PARALLEL_FRONTIER).clamp(1, shards.max(1));
    let (mut improved, newly) = match pool {
        Some(pool) if fshards > 1 => {
            let cuts = frontier_cuts(g, frontier, dir, fshards);
            let slots = pool.scope_slots(fshards, |i| {
                let mut improved = Vec::new();
                let mut newly = Vec::new();
                for &v in &frontier[cuts[i]..cuts[i + 1]] {
                    relax(v, &mut improved, &mut newly);
                }
                (improved, newly)
            });
            let mut improved = Vec::new();
            let mut newly = Vec::new();
            for (imp, tch) in slots {
                improved.extend(imp);
                newly.extend(tch);
            }
            (improved, newly)
        }
        _ => {
            let mut improved = Vec::new();
            let mut newly = Vec::new();
            for &v in frontier {
                relax(v, &mut improved, &mut newly);
            }
            (improved, newly)
        }
    };
    improved.sort_unstable();
    improved.dedup();
    (improved, newly)
}

/// Frontier-parallel twin of [`bfs_budgeted`]: monotone best-budget
/// relaxation in level-synchronous rounds over `pool`. The fixed point
/// of the max-relaxation is unique regardless of schedule, so the
/// returned vertex set — every vertex whose final remaining budget is
/// positive, ascending by index — is **identical to the serial
/// [`bfs_budgeted`] output** for every shard count. Touched entries are
/// dirty-reset before returning (no O(|V|) scan: first-touch claims are
/// collected during relaxation).
pub fn bfs_budgeted_pooled(
    g: &DynamicGraph,
    seeds: &[(VertexIdx, u32)],
    dir: Direction,
    scratch: &mut BfsScratch,
    pool: Option<&ThreadPool>,
    shards: usize,
) -> Vec<VertexIdx> {
    scratch.ensure(g.num_vertices());
    let remaining = &scratch.remaining;
    let mut touched: Vec<VertexIdx> = Vec::new();
    let mut frontier: Vec<VertexIdx> = Vec::new();
    for &(s, b) in seeds {
        let r = b.saturating_add(1);
        let old = remaining[s as usize].fetch_max(r, Ordering::Relaxed);
        if old == 0 {
            touched.push(s);
        }
        if old < r {
            frontier.push(s);
        }
    }
    frontier.sort_unstable();
    frontier.dedup();
    while !frontier.is_empty() {
        let (next, newly) = relax_level(g, &frontier, dir, remaining, pool, shards);
        touched.extend_from_slice(&newly);
        frontier = next;
    }
    touched.sort_unstable();
    for &v in &touched {
        remaining[v as usize].store(0, Ordering::Relaxed);
    }
    touched
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dynamic::DynamicGraph;

    /// Path graph 0 -> 1 -> 2 -> 3 -> 4 (ids == indices).
    fn path() -> DynamicGraph {
        let (g, _) = DynamicGraph::from_edges((0..4).map(|i| (i, i + 1)));
        g
    }

    #[test]
    fn bfs_depth_limits() {
        let g = path();
        let r = bfs_multi(&g, &[0], 2, Direction::Out);
        let mut got: Vec<_> = r.iter().map(|&(v, d)| (v, d)).collect();
        got.sort_unstable();
        assert_eq!(got, vec![(0, 0), (1, 1), (2, 2)]);
    }

    #[test]
    fn bfs_depth_zero_returns_seeds_only() {
        let g = path();
        let r = bfs_multi(&g, &[2], 0, Direction::Both);
        assert_eq!(r, vec![(2, 0)]);
    }

    #[test]
    fn bfs_direction_in_walks_backwards() {
        let g = path();
        let r = bfs_multi(&g, &[4], 10, Direction::In);
        assert_eq!(r.len(), 5);
        let r_out = bfs_multi(&g, &[4], 10, Direction::Out);
        assert_eq!(r_out.len(), 1);
    }

    #[test]
    fn bfs_both_reaches_everything_from_middle() {
        let g = path();
        let r = bfs_multi(&g, &[2], 10, Direction::Both);
        assert_eq!(r.len(), 5);
    }

    #[test]
    fn multi_source_reports_min_depth() {
        let g = path();
        let r = bfs_multi(&g, &[0, 3], 1, Direction::Out);
        let mut got: Vec<_> = r.into_iter().collect();
        got.sort_unstable();
        assert_eq!(got, vec![(0, 0), (1, 1), (3, 0), (4, 1)]);
    }

    #[test]
    fn budgeted_respects_per_seed_budgets() {
        let g = path();
        // seed 0 with budget 1, seed 3 with budget 0
        let mut r = bfs_budgeted(&g, &[(0, 1), (3, 0)], Direction::Out);
        r.sort_unstable();
        assert_eq!(r, vec![0, 1, 3]);
    }

    #[test]
    fn budgeted_takes_best_budget_on_overlap() {
        let g = path();
        // seed 0 twice: once with 0, once with 3 — the larger must win.
        let mut r = bfs_budgeted(&g, &[(0, 0), (0, 3)], Direction::Out);
        r.sort_unstable();
        assert_eq!(r, vec![0, 1, 2, 3]);
    }

    #[test]
    fn budgeted_empty_seeds() {
        let g = path();
        assert!(bfs_budgeted(&g, &[], Direction::Both).is_empty());
    }

    #[test]
    fn budgeted_equals_uniform_bfs_when_budgets_equal() {
        let (g, _) = DynamicGraph::from_edges(vec![
            (0, 1), (1, 2), (2, 0), (2, 3), (3, 4), (4, 5), (5, 3), (1, 4),
        ]);
        let seeds = [0u32, 5u32];
        let uniform: std::collections::BTreeSet<u32> =
            bfs_multi(&g, &seeds, 2, Direction::Both).into_iter().map(|(v, _)| v).collect();
        let budgeted: std::collections::BTreeSet<u32> =
            bfs_budgeted(&g, &[(0, 2), (5, 2)], Direction::Both).into_iter().collect();
        assert_eq!(uniform, budgeted);
    }

    /// A tangled graph with hubs, a chain tail and isolated vertices.
    fn tangled() -> DynamicGraph {
        let mut edges = Vec::new();
        for v in 1..30u64 {
            edges.push((0, v)); // hub out
            if v % 3 == 0 {
                edges.push((v, 0)); // some back-edges
            }
            if v + 1 < 30 && v % 4 != 0 {
                edges.push((v, v + 1));
            }
        }
        let (mut g, _) = DynamicGraph::from_edges(edges);
        g.add_vertex(100); // isolated
        g.add_vertex(101);
        g
    }

    fn sorted_pairs(mut v: Vec<(VertexIdx, u32)>) -> Vec<(VertexIdx, u32)> {
        v.sort_unstable();
        v
    }

    #[test]
    fn pooled_multi_matches_serial_for_every_shard_count() {
        let g = tangled();
        let pool = crate::util::threadpool::ThreadPool::new(4);
        let mut scratch = BfsScratch::new();
        for dir in [Direction::Out, Direction::In, Direction::Both] {
            for depth in [0u32, 1, 2, 5] {
                let seeds = [2u32, 7, 7, 19];
                let serial = sorted_pairs(bfs_multi(&g, &seeds, depth, dir));
                for shards in [1usize, 2, 4, 7] {
                    let pooled = bfs_multi_pooled(
                        &g,
                        &seeds,
                        depth,
                        dir,
                        &mut scratch,
                        Some(&pool),
                        shards,
                    );
                    assert_eq!(sorted_pairs(pooled), serial, "dir={dir:?} d={depth} k={shards}");
                }
                // No pool ⇒ inline path, same answer.
                let inline = bfs_multi_pooled(&g, &seeds, depth, dir, &mut scratch, None, 1);
                assert_eq!(sorted_pairs(inline), serial);
            }
        }
    }

    #[test]
    fn pooled_multi_reports_levels_in_deterministic_order() {
        let g = tangled();
        let pool = crate::util::threadpool::ThreadPool::new(4);
        let mut scratch = BfsScratch::new();
        let out = bfs_multi_pooled(&g, &[0, 5], 3, Direction::Both, &mut scratch, None, 1);
        // Depths ascend; within a level (past the seeds) indices ascend.
        let mut prev: Option<(u32, VertexIdx)> = None;
        for &(v, d) in &out {
            if let Some((pd, pv)) = prev {
                assert!(d >= pd, "depths must be non-decreasing");
                if d == pd && d > 0 {
                    assert!(v > pv, "within-level order must ascend");
                }
            }
            prev = Some((d, v));
        }
        // The exact output vector is shard-count-independent.
        for shards in [2usize, 4, 7] {
            let p = Some(&pool);
            let again = bfs_multi_pooled(&g, &[0, 5], 3, Direction::Both, &mut scratch, p, shards);
            assert_eq!(again, out, "shards={shards}");
        }
    }

    #[test]
    fn pooled_budgeted_matches_serial_bit_for_bit() {
        let g = tangled();
        let pool = crate::util::threadpool::ThreadPool::new(4);
        let mut scratch = BfsScratch::new();
        let seeds = [(0u32, 2u32), (9, 0), (9, 4), (25, 1)];
        for dir in [Direction::Out, Direction::In, Direction::Both] {
            let serial = bfs_budgeted(&g, &seeds, dir);
            for shards in [1usize, 2, 4, 7] {
                let pooled =
                    bfs_budgeted_pooled(&g, &seeds, dir, &mut scratch, Some(&pool), shards);
                assert_eq!(pooled, serial, "dir={dir:?} k={shards}");
            }
            let inline = bfs_budgeted_pooled(&g, &seeds, dir, &mut scratch, None, 1);
            assert_eq!(inline, serial);
        }
    }

    #[test]
    fn scratch_dirty_reset_makes_reuse_exact() {
        // Back-to-back walks over ONE scratch must match fresh-scratch
        // runs — a leaked depth/budget entry would poison the second.
        let g = tangled();
        let mut scratch = BfsScratch::new();
        let a1 = bfs_multi_pooled(&g, &[0], 2, Direction::Out, &mut scratch, None, 1);
        let b1 = bfs_budgeted_pooled(&g, &[(3, 3)], Direction::Both, &mut scratch, None, 1);
        let a2 = bfs_multi_pooled(&g, &[0], 2, Direction::Out, &mut scratch, None, 1);
        let b2 = bfs_budgeted_pooled(&g, &[(3, 3)], Direction::Both, &mut scratch, None, 1);
        assert_eq!(a1, a2);
        assert_eq!(b1, b2);
        let mut fresh = BfsScratch::new();
        assert_eq!(a2, bfs_multi_pooled(&g, &[0], 2, Direction::Out, &mut fresh, None, 1));
        assert_eq!(b2, bfs_budgeted_pooled(&g, &[(3, 3)], Direction::Both, &mut fresh, None, 1));
    }

    #[test]
    fn pooled_walks_handle_empty_graph_and_empty_seeds() {
        let g = DynamicGraph::new();
        let mut scratch = BfsScratch::new();
        assert!(bfs_multi_pooled(&g, &[], 3, Direction::Both, &mut scratch, None, 1).is_empty());
        assert!(bfs_budgeted_pooled(&g, &[], Direction::Both, &mut scratch, None, 1).is_empty());
        let g = tangled();
        assert!(bfs_multi_pooled(&g, &[], 3, Direction::Both, &mut scratch, None, 1).is_empty());
    }
}
