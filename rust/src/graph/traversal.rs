//! Breadth-first neighborhood expansion.
//!
//! The paper's `K_n` (Eq. 3) expands a uniform diameter `n` around the
//! seed set `K_r`; `K_Δ` (Eq. 4) expands a *per-vertex* radius `f_Δ(v)`.
//! Both reduce to a multi-source BFS with per-frontier-vertex depth
//! budgets, implemented here over the [`DynamicGraph`] adjacency (both
//! edge directions — update locality propagates along either).

use std::collections::VecDeque;

use crate::graph::dynamic::DynamicGraph;
use crate::graph::VertexIdx;

/// Which adjacency to walk during expansion.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    Out,
    In,
    Both,
}

fn push_neighbors(
    g: &DynamicGraph,
    v: VertexIdx,
    dir: Direction,
    mut f: impl FnMut(VertexIdx),
) {
    if matches!(dir, Direction::Out | Direction::Both) {
        for &w in g.out_neighbors(v) {
            f(w);
        }
    }
    if matches!(dir, Direction::In | Direction::Both) {
        for &w in g.in_neighbors(v) {
            f(w);
        }
    }
}

/// Multi-source BFS up to `max_depth` hops; returns `(vertex, depth)` for
/// every vertex reached (seeds at depth 0, each vertex reported once at
/// its minimum depth).
pub fn bfs_multi(
    g: &DynamicGraph,
    seeds: &[VertexIdx],
    max_depth: u32,
    dir: Direction,
) -> Vec<(VertexIdx, u32)> {
    let n = g.num_vertices();
    let mut depth = vec![u32::MAX; n];
    let mut out = Vec::new();
    let mut q = VecDeque::new();
    for &s in seeds {
        if depth[s as usize] == u32::MAX {
            depth[s as usize] = 0;
            out.push((s, 0));
            q.push_back(s);
        }
    }
    while let Some(v) = q.pop_front() {
        let d = depth[v as usize];
        if d >= max_depth {
            continue;
        }
        push_neighbors(g, v, dir, |w| {
            if depth[w as usize] == u32::MAX {
                depth[w as usize] = d + 1;
                out.push((w, d + 1));
                q.push_back(w);
            }
        });
    }
    out
}

/// BFS where each seed carries its own depth budget (the `K_Δ` shape):
/// vertex `w` is reached if some seed `s` with budget `b_s` satisfies
/// `dist(s, w) <= b_s`. Implemented as a best-budget propagation: the
/// frontier carries the *remaining* budget, and a vertex is re-expanded
/// only if reached with a strictly larger remaining budget.
pub fn bfs_budgeted(
    g: &DynamicGraph,
    seeds: &[(VertexIdx, u32)],
    dir: Direction,
) -> Vec<VertexIdx> {
    let n = g.num_vertices();
    // remaining[v] = best remaining budget when v was reached (+1 offset; 0
    // = unreached).
    let mut remaining = vec![0u32; n];
    let mut q = VecDeque::new();
    for &(s, b) in seeds {
        let r = b.saturating_add(1);
        if r > remaining[s as usize] {
            remaining[s as usize] = r;
            q.push_back(s);
        }
    }
    let mut out: Vec<VertexIdx> = Vec::new();
    while let Some(v) = q.pop_front() {
        let r = remaining[v as usize];
        if r <= 1 {
            continue; // no budget left to expand
        }
        push_neighbors(g, v, dir, |w| {
            if r - 1 > remaining[w as usize] {
                remaining[w as usize] = r - 1;
                q.push_back(w);
            }
        });
    }
    for v in 0..n {
        if remaining[v] > 0 {
            out.push(v as VertexIdx);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dynamic::DynamicGraph;

    /// Path graph 0 -> 1 -> 2 -> 3 -> 4 (ids == indices).
    fn path() -> DynamicGraph {
        let (g, _) = DynamicGraph::from_edges((0..4).map(|i| (i, i + 1)));
        g
    }

    #[test]
    fn bfs_depth_limits() {
        let g = path();
        let r = bfs_multi(&g, &[0], 2, Direction::Out);
        let mut got: Vec<_> = r.iter().map(|&(v, d)| (v, d)).collect();
        got.sort_unstable();
        assert_eq!(got, vec![(0, 0), (1, 1), (2, 2)]);
    }

    #[test]
    fn bfs_depth_zero_returns_seeds_only() {
        let g = path();
        let r = bfs_multi(&g, &[2], 0, Direction::Both);
        assert_eq!(r, vec![(2, 0)]);
    }

    #[test]
    fn bfs_direction_in_walks_backwards() {
        let g = path();
        let r = bfs_multi(&g, &[4], 10, Direction::In);
        assert_eq!(r.len(), 5);
        let r_out = bfs_multi(&g, &[4], 10, Direction::Out);
        assert_eq!(r_out.len(), 1);
    }

    #[test]
    fn bfs_both_reaches_everything_from_middle() {
        let g = path();
        let r = bfs_multi(&g, &[2], 10, Direction::Both);
        assert_eq!(r.len(), 5);
    }

    #[test]
    fn multi_source_reports_min_depth() {
        let g = path();
        let r = bfs_multi(&g, &[0, 3], 1, Direction::Out);
        let mut got: Vec<_> = r.into_iter().collect();
        got.sort_unstable();
        assert_eq!(got, vec![(0, 0), (1, 1), (3, 0), (4, 1)]);
    }

    #[test]
    fn budgeted_respects_per_seed_budgets() {
        let g = path();
        // seed 0 with budget 1, seed 3 with budget 0
        let mut r = bfs_budgeted(&g, &[(0, 1), (3, 0)], Direction::Out);
        r.sort_unstable();
        assert_eq!(r, vec![0, 1, 3]);
    }

    #[test]
    fn budgeted_takes_best_budget_on_overlap() {
        let g = path();
        // seed 0 twice: once with 0, once with 3 — the larger must win.
        let mut r = bfs_budgeted(&g, &[(0, 0), (0, 3)], Direction::Out);
        r.sort_unstable();
        assert_eq!(r, vec![0, 1, 2, 3]);
    }

    #[test]
    fn budgeted_empty_seeds() {
        let g = path();
        assert!(bfs_budgeted(&g, &[], Direction::Both).is_empty());
    }

    #[test]
    fn budgeted_equals_uniform_bfs_when_budgets_equal() {
        let (g, _) = DynamicGraph::from_edges(vec![
            (0, 1), (1, 2), (2, 0), (2, 3), (3, 4), (4, 5), (5, 3), (1, 4),
        ]);
        let seeds = [0u32, 5u32];
        let uniform: std::collections::BTreeSet<u32> =
            bfs_multi(&g, &seeds, 2, Direction::Both).into_iter().map(|(v, _)| v).collect();
        let budgeted: std::collections::BTreeSet<u32> =
            bfs_budgeted(&g, &[(0, 2), (5, 2)], Direction::Both).into_iter().collect();
        assert_eq!(uniform, budgeted);
    }
}
