//! Unified error type for the VeilGraph library.

use thiserror::Error;

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All errors surfaced by VeilGraph public APIs.
#[derive(Error, Debug)]
pub enum Error {
    /// A vertex id referenced by an operation does not exist in the graph.
    #[error("unknown vertex {0}")]
    UnknownVertex(u64),

    /// An edge referenced by an operation does not exist in the graph.
    #[error("unknown edge ({0}, {1})")]
    UnknownEdge(u64, u64),

    /// Malformed input data (edge lists, streams, configs).
    #[error("parse error: {0}")]
    Parse(String),

    /// Malformed or inconsistent JSON.
    #[error("json error: {0}")]
    Json(String),

    /// CLI usage error.
    #[error("usage error: {0}")]
    Usage(String),

    /// A required AOT artifact is missing or inconsistent.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// The PJRT runtime rejected a load/compile/execute call.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Engine state machine misuse (e.g. query before initial compute).
    #[error("engine error: {0}")]
    Engine(String),

    /// Capacity exceeded (summary larger than the largest artifact and no
    /// fallback allowed).
    #[error("capacity error: need {needed}, max {max}")]
    Capacity { needed: usize, max: usize },

    /// Backpressure: the ingestion queue is full and the policy is Reject.
    #[error("backpressure: queue full ({0} pending)")]
    Backpressure(usize),

    /// Underlying I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_are_informative() {
        assert_eq!(Error::UnknownVertex(7).to_string(), "unknown vertex 7");
        assert_eq!(
            Error::Capacity { needed: 4096, max: 2048 }.to_string(),
            "capacity error: need 4096, max 2048"
        );
        assert!(Error::Parse("bad line".into()).to_string().contains("bad line"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
