//! Unified error type for the VeilGraph library.
//!
//! Hand-rolled `Display`/`Error` impls (substrate for the unavailable
//! `thiserror` crate) — the std-only build has no proc macros.

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All errors surfaced by VeilGraph public APIs.
#[derive(Debug)]
pub enum Error {
    /// A vertex id referenced by an operation does not exist in the graph.
    UnknownVertex(u64),

    /// An edge referenced by an operation does not exist in the graph.
    UnknownEdge(u64, u64),

    /// Malformed input data (edge lists, streams, configs).
    Parse(String),

    /// Malformed or inconsistent JSON.
    Json(String),

    /// CLI usage error.
    Usage(String),

    /// A required AOT artifact is missing or inconsistent.
    Artifact(String),

    /// The summarized runtime rejected a load/compile/execute call.
    Runtime(String),

    /// Engine state machine misuse (e.g. query before initial compute).
    Engine(String),

    /// Capacity exceeded (summary larger than the largest artifact and no
    /// fallback allowed).
    Capacity { needed: usize, max: usize },

    /// Backpressure: the ingestion queue is full and the policy is Reject.
    Backpressure(usize),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::UnknownVertex(v) => write!(f, "unknown vertex {v}"),
            Error::UnknownEdge(u, v) => write!(f, "unknown edge ({u}, {v})"),
            Error::Parse(msg) => write!(f, "parse error: {msg}"),
            Error::Json(msg) => write!(f, "json error: {msg}"),
            Error::Usage(msg) => write!(f, "usage error: {msg}"),
            Error::Artifact(msg) => write!(f, "artifact error: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Engine(msg) => write!(f, "engine error: {msg}"),
            Error::Capacity { needed, max } => {
                write!(f, "capacity error: need {needed}, max {max}")
            }
            Error::Backpressure(n) => write!(f, "backpressure: queue full ({n} pending)"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_are_informative() {
        assert_eq!(Error::UnknownVertex(7).to_string(), "unknown vertex 7");
        assert_eq!(
            Error::Capacity { needed: 4096, max: 2048 }.to_string(),
            "capacity error: need 4096, max 2048"
        );
        assert!(Error::Parse("bad line".into()).to_string().contains("bad line"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }

    #[test]
    fn source_chains_io_errors() {
        use std::error::Error as _;
        let e: Error = std::io::Error::other("disk").into();
        assert!(e.source().is_some());
        assert!(Error::Engine("state".into()).source().is_none());
    }
}
