//! PageRank: the exact power-method baseline and the rust-native
//! summarized executor (the XLA-backed executor lives in
//! [`crate::runtime`]).

pub mod power;
pub mod sharded;
pub mod summarized;
