//! Rust-native summarized PageRank over a [`SummaryGraph`].
//!
//! Semantically identical to the XLA path (L2/L1 artifacts) — this sparse
//! executor is (a) the fallback when `|K|` exceeds the largest AOT
//! capacity, (b) the cross-check oracle for the runtime integration
//! tests, and (c) ablation A1's comparison point.
//!
//! Update rule over the summary graph (teleport uses the FULL graph's
//! |V| so summary ranks remain comparable to full ranks):
//!
//! ```text
//! r'_z = (1-β)/n + β · ( Σ_{(u,z) ∈ E_K} val((u,z)) · r_u  +  b_z )
//! ```

use crate::pagerank::power::PageRankConfig;
use crate::summary::bigvertex::SummaryGraph;
use crate::util::threadpool::ThreadPool;

/// Result of a summarized run (ranks are per *local* summary index).
#[derive(Clone, Debug)]
pub struct SummarizedResult {
    pub ranks: Vec<f64>,
    pub iterations: usize,
    pub last_delta: f64,
}

/// Run the summarized power method starting from the summary's warm-start
/// ranks (`r0` = previous measurement point's ranks of the hot vertices).
pub fn run_summarized(s: &SummaryGraph, cfg: &PageRankConfig) -> SummarizedResult {
    let k = s.num_vertices();
    if k == 0 {
        return SummarizedResult { ranks: vec![], iterations: 0, last_delta: 0.0 };
    }
    let teleport = cfg.teleport(s.full_n);
    let epsilon = cfg.scaled_epsilon(s.full_n);
    let mut ranks = s.r0.clone();
    let mut next = vec![0.0f64; k];
    let mut iterations = 0;
    let mut last_delta = f64::INFINITY;
    for _ in 0..cfg.max_iters {
        let mut delta = 0.0;
        for z in 0..k {
            let mut sum = s.b[z];
            for &(u, w) in s.row(z) {
                sum += w as f64 * ranks[u as usize];
            }
            let x = teleport + cfg.beta * sum;
            delta += (x - ranks[z]).abs();
            next[z] = x;
        }
        iterations += 1;
        last_delta = delta;
        std::mem::swap(&mut ranks, &mut next);
        if cfg.epsilon > 0.0 && last_delta < epsilon {
            break;
        }
    }
    SummarizedResult { ranks, iterations, last_delta }
}

/// Sharded twin of [`run_summarized`]: local summary vertices are cut
/// into [`PageRankConfig::parallelism`]-many internal-in-edge-balanced
/// shards ([`SummaryGraph::shards`]; `0` = one per pool worker) and each
/// iteration dispatches one gather job per shard over `pool`. Per-vertex
/// sums run in the serial order, so ranks are bit-identical to the serial
/// executor's; the L1 delta reduces per-shard then in shard order —
/// deterministic for a fixed shard count.
pub fn run_summarized_parallel(
    s: &SummaryGraph,
    cfg: &PageRankConfig,
    pool: &ThreadPool,
) -> SummarizedResult {
    let k = s.num_vertices();
    if k == 0 {
        return SummarizedResult { ranks: vec![], iterations: 0, last_delta: 0.0 };
    }
    let shards = cfg.effective_shards(pool);
    if shards <= 1 {
        return run_summarized(s, cfg);
    }
    let teleport = cfg.teleport(s.full_n);
    let epsilon = cfg.scaled_epsilon(s.full_n);
    let cuts = s.shards(shards);
    let mut ranks = s.r0.clone();
    let mut next = vec![0.0f64; k];
    let mut iterations = 0;
    let mut last_delta = f64::INFINITY;
    for _ in 0..cfg.max_iters {
        let partials = {
            let ranks = &ranks;
            let cuts_ref = &cuts;
            pool.scope_chunks(&mut next, &cuts, move |i, chunk| {
                let lo = cuts_ref[i];
                let mut delta = 0.0f64;
                for (off, slot) in chunk.iter_mut().enumerate() {
                    let z = lo + off;
                    let mut sum = s.b[z];
                    for &(u, w) in s.row(z) {
                        sum += w as f64 * ranks[u as usize];
                    }
                    let x = teleport + cfg.beta * sum;
                    delta += (x - ranks[z]).abs();
                    *slot = x;
                }
                delta
            })
        };
        iterations += 1;
        last_delta = partials.iter().sum();
        std::mem::swap(&mut ranks, &mut next);
        if cfg.epsilon > 0.0 && last_delta < epsilon {
            break;
        }
    }
    SummarizedResult { ranks, iterations, last_delta }
}

/// Merge summarized ranks back into the full rank vector **in place**:
/// hot vertices take their recomputed scores, everything else keeps its
/// previous rank (“outside vertices are not worth recomputing” — §3).
/// `ranks` is truncated/grown to the summary's full |V| (new vertices
/// get the `(1-β)/n` default) and then the |K| hot entries are
/// overwritten — no fresh |V| vector per query; the engine updates its
/// long-lived rank vector with exactly O(|K|) writes in the steady
/// state.
pub fn merge_ranks_into(
    ranks: &mut Vec<f64>,
    s: &SummaryGraph,
    summarized: &[f64],
    default_rank: f64,
) {
    ranks.truncate(s.full_n);
    ranks.resize(s.full_n, default_rank);
    for (li, &v) in s.vertices.iter().enumerate() {
        ranks[v as usize] = summarized[li];
    }
}

/// Allocating wrapper over [`merge_ranks_into`] — returns the updated
/// full vector, leaving `prev` untouched.
pub fn merge_ranks(
    prev: &[f64],
    s: &SummaryGraph,
    summarized: &[f64],
    default_rank: f64,
) -> Vec<f64> {
    let mut out = prev.to_vec();
    merge_ranks_into(&mut out, s, summarized, default_rank);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dynamic::DynamicGraph;
    use crate::pagerank::power::PageRank;
    use crate::summary::hot::HotSet;

    fn full_hot(g: &DynamicGraph) -> HotSet {
        let idxs: Vec<u32> = (0..g.num_vertices() as u32).collect();
        let hot = vec![true; g.num_vertices()];
        HotSet { k_r: idxs.clone(), k_n: vec![], k_delta: vec![], hot }
    }

    fn cfg() -> PageRankConfig {
        PageRankConfig {
            beta: 0.85,
            max_iters: 200,
            epsilon: 1e-12,
            normalized: true,
            ..Default::default()
        }
    }

    /// When K = V the summary graph IS the graph: summarized PageRank must
    /// equal the exact power method.
    #[test]
    fn full_hot_set_reduces_to_exact_pagerank() {
        let (g, _) = DynamicGraph::from_edges(vec![
            (0, 1), (1, 2), (2, 0), (2, 3), (3, 0), (0, 3), (3, 4), (4, 2),
        ]);
        let n = g.num_vertices();
        let prev = vec![1.0 / n as f64; n];
        let s = SummaryGraph::build(&g, &full_hot(&g), &prev, 0.0);
        assert_eq!(s.num_boundary_edges, 0);
        let sr = run_summarized(&s, &cfg());
        let exact = PageRank::new(cfg()).run(&g.snapshot());
        for (li, &v) in s.vertices.iter().enumerate() {
            assert!(
                (sr.ranks[li] - exact.ranks[v as usize]).abs() < 1e-9,
                "vertex {v}: {} vs {}",
                sr.ranks[li],
                exact.ranks[v as usize]
            );
        }
    }

    /// Langville–Meyer sanity: if the graph did not change and prev ranks
    /// are the exact fixed point, the summarized run must stay at that
    /// fixed point regardless of which K was chosen.
    #[test]
    fn fixed_point_is_preserved_for_any_hot_set() {
        let (g, _) = DynamicGraph::from_edges(vec![
            (0, 1), (1, 2), (2, 0), (2, 3), (3, 0), (0, 3), (3, 4), (4, 2), (1, 4),
        ]);
        let exact = PageRank::new(cfg()).run(&g.snapshot());
        for k_set in [vec![0u32, 1], vec![2u32, 3, 4], vec![1u32]] {
            let mut hot = vec![false; g.num_vertices()];
            for &i in &k_set {
                hot[i as usize] = true;
            }
            let hs = HotSet { k_r: k_set.clone(), k_n: vec![], k_delta: vec![], hot };
            let s = SummaryGraph::build(&g, &hs, &exact.ranks, 0.0);
            let sr = run_summarized(&s, &cfg());
            for (li, &v) in s.vertices.iter().enumerate() {
                assert!(
                    (sr.ranks[li] - exact.ranks[v as usize]).abs() < 1e-9,
                    "K={k_set:?} vertex {v}"
                );
            }
        }
    }

    #[test]
    fn empty_summary_is_noop() {
        let (g, _) = DynamicGraph::from_edges(vec![(0, 1)]);
        let hs = HotSet { k_r: vec![], k_n: vec![], k_delta: vec![], hot: vec![false; 2] };
        let s = SummaryGraph::build(&g, &hs, &[0.5, 0.5], 0.0);
        let sr = run_summarized(&s, &cfg());
        assert!(sr.ranks.is_empty());
        let merged = merge_ranks(&[0.5, 0.5], &s, &sr.ranks, 0.15 / 2.0);
        assert_eq!(merged, vec![0.5, 0.5]);
    }

    #[test]
    fn merge_overwrites_only_hot_vertices() {
        let (g, _) = DynamicGraph::from_edges(vec![(0, 1), (1, 2), (2, 0)]);
        let mut hot = vec![false; 3];
        hot[1] = true;
        let hs = HotSet { k_r: vec![1], k_n: vec![], k_delta: vec![], hot };
        let prev = vec![0.3, 0.3, 0.4];
        let s = SummaryGraph::build(&g, &hs, &prev, 0.0);
        let merged = merge_ranks(&prev, &s, &[0.9], 0.1);
        assert_eq!(merged, vec![0.3, 0.9, 0.4]);
    }

    #[test]
    fn merge_into_matches_allocating_merge() {
        let (g, _) = DynamicGraph::from_edges(vec![(0, 1), (1, 2), (2, 0), (2, 3)]);
        let mut hot = vec![false; 4];
        hot[0] = true;
        hot[2] = true;
        let hs = HotSet { k_r: vec![0, 2], k_n: vec![], k_delta: vec![], hot };
        let prev = vec![0.1, 0.2, 0.3, 0.4];
        let s = SummaryGraph::build(&g, &hs, &prev, 0.0);
        let summarized = vec![0.7, 0.9];
        let out = merge_ranks(&prev, &s, &summarized, 0.05);
        let mut in_place = prev.clone();
        merge_ranks_into(&mut in_place, &s, &summarized, 0.05);
        assert_eq!(in_place, out);
        assert_eq!(in_place, vec![0.7, 0.2, 0.9, 0.4]);
        // A longer-than-|V| previous vector truncates either way.
        let long = vec![0.5; 9];
        let out = merge_ranks(&long, &s, &summarized, 0.05);
        let mut in_place = long.clone();
        merge_ranks_into(&mut in_place, &s, &summarized, 0.05);
        assert_eq!(in_place, out);
        assert_eq!(in_place.len(), 4);
    }

    #[test]
    fn merge_grows_vector_for_new_vertices() {
        let (g, _) = DynamicGraph::from_edges(vec![(0, 1), (1, 2), (2, 3)]);
        let mut hot = vec![false; 4];
        hot[3] = true;
        let hs = HotSet { k_r: vec![3], k_n: vec![], k_delta: vec![], hot };
        let prev = vec![0.3, 0.3]; // graph grew from 2 to 4 vertices
        let s = SummaryGraph::build(&g, &hs, &prev, 0.0);
        let sr = run_summarized(&s, &cfg());
        let merged = merge_ranks(&prev, &s, &sr.ranks, 0.15 / 4.0);
        assert_eq!(merged.len(), 4);
        assert_eq!(merged[0], 0.3);
        let default = 0.15 / 4.0;
        assert!((merged[2] - default).abs() < 1e-12, "untouched new vertex gets default");
        assert_eq!(merged[3], sr.ranks[0]);
    }

    #[test]
    fn convergence_reported() {
        let (g, _) = DynamicGraph::from_edges(vec![(0, 1), (1, 0)]);
        // Start far from the fixed point so convergence takes >1 iteration.
        let s = SummaryGraph::build(&g, &full_hot(&g), &[0.9, 0.1], 0.0);
        let sr = run_summarized(&s, &cfg());
        assert!(sr.last_delta < 1e-12);
        assert!(sr.iterations > 1);
    }

    #[test]
    fn parallel_summarized_matches_serial_bit_for_bit() {
        let pool = ThreadPool::new(4);
        let (g, _) = DynamicGraph::from_edges(vec![
            (0, 1), (1, 2), (2, 0), (2, 3), (3, 0), (0, 3), (3, 4), (4, 2), (1, 4), (5, 0),
            (0, 5), (5, 6), (6, 5),
        ]);
        let n = g.num_vertices();
        let prev: Vec<f64> = (0..n).map(|v| 0.05 + 0.1 * v as f64).collect();
        // Partial hot set ⇒ both internal and boundary edges exist.
        let k_set = vec![0u32, 2, 3, 5, 6];
        let mut hot = vec![false; n];
        for &i in &k_set {
            hot[i as usize] = true;
        }
        let hs = HotSet { k_r: k_set, k_n: vec![], k_delta: vec![], hot };
        let s = SummaryGraph::build(&g, &hs, &prev, 0.0);
        let mut c = cfg();
        c.epsilon = 0.0;
        c.max_iters = 25;
        let serial = run_summarized(&s, &c);
        for shards in [2usize, 3, 4, 7, 32] {
            c.parallelism = shards;
            let par = run_summarized_parallel(&s, &c, &pool);
            assert_eq!(par.iterations, serial.iterations);
            assert_eq!(par.ranks, serial.ranks, "shards={shards}");
        }
    }

    #[test]
    fn parallel_summarized_handles_empty_and_single_shard() {
        let pool = ThreadPool::new(2);
        let (g, _) = DynamicGraph::from_edges(vec![(0, 1)]);
        let hs = HotSet { k_r: vec![], k_n: vec![], k_delta: vec![], hot: vec![false; 2] };
        let s = SummaryGraph::build(&g, &hs, &[0.5, 0.5], 0.0);
        let mut c = cfg();
        c.parallelism = 4;
        let sr = run_summarized_parallel(&s, &c, &pool);
        assert!(sr.ranks.is_empty());
        // parallelism = 1 falls back to the serial code path
        let s2 = SummaryGraph::build(&g, &full_hot(&g), &[0.5, 0.5], 0.0);
        c.parallelism = 1;
        let serial = run_summarized(&s2, &cfg());
        let one = run_summarized_parallel(&s2, &c, &pool);
        assert_eq!(one.ranks, serial.ranks);
    }
}
