//! Cross-shard PageRank with a boundary-rank exchange step per
//! iteration.
//!
//! Each shard owns the out-edges of its vertices (source-routed
//! partition, [`crate::graph::partition::Partitioner`]). One global
//! power-method iteration becomes, per shard:
//!
//! 1. **Scatter** — scale every owned source once: `c_u = r_u /
//!    d_out(u)`. `d_out` is exact because all of `u`'s out-edges live on
//!    its owner.
//! 2. **Local gather** — accumulate `c_u` over internal edges (both
//!    endpoints owned here).
//! 3. **Boundary exchange** — accumulate `c_u` over cut edges into the
//!    destination shard's [`RemoteAggregate`] inbox (the remote shard is
//!    "just another big vertex": per-target rolled-up boundary mass,
//!    exactly the `b_z` shape of `summary/bigvertex.rs`, except
//!    re-exchanged every iteration instead of frozen once).
//! 4. **Apply** — `next_v = teleport + β·(local_v + inbox_v) [+
//!    dangling]` for owned `v`; per-shard L1 deltas reduce in shard
//!    order into the global convergence test.
//!
//! Every owned vertex receives exactly the contributions the
//! single-engine gather sums for it, under the same teleport, init,
//! dangling and `scaled_epsilon(n_total)` semantics
//! ([`crate::pagerank::power`]) — so the exchange converges to the same
//! fixed point. Floating-point summation *order* differs (a vertex's
//! in-mass splits into local + per-shard inbox partial sums), which is
//! why sharded-vs-single equivalence is stated as a tolerance
//! (`L1 < 1e-6` in the property tests), not bit-identity.

use crate::graph::dynamic::DynamicGraph;
use crate::graph::partition::Partitioner;
use crate::graph::VertexIdx;
use crate::pagerank::power::PageRankConfig;
use crate::summary::bigvertex::RemoteAggregate;

/// The frozen exchange topology for one recompute: per-shard internal
/// edge lists plus cut-edge lists pre-resolved to *destination-local*
/// indices, so the iteration loop never touches an id map.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// Per shard: local indices of the vertices it owns (ghosts skipped).
    owned: Vec<Vec<VertexIdx>>,
    /// Per shard: `1/d_out` per local index (0 for dangling and ghosts).
    inv_out: Vec<Vec<f64>>,
    /// Per shard: internal edges `(src_local, dst_local)`.
    internal: Vec<Vec<(VertexIdx, VertexIdx)>>,
    /// `cross[s][t]`: cut edges from shard `s` into shard `t`, as
    /// `(src_local_in_s, dst_local_in_t)`.
    cross: Vec<Vec<Vec<(VertexIdx, VertexIdx)>>>,
    /// Per shard: local vector length (`graph.num_vertices()`, ghosts
    /// included).
    len: Vec<usize>,
    /// Union of owned vertices — the single-engine `|V|`.
    n_total: usize,
    /// Total cut edges (boundary edges between shards).
    cut_edges: usize,
}

impl ShardPlan {
    /// Freeze the exchange topology from per-shard graphs. Ownership is
    /// re-derived from the partitioner (ghosts are skipped), and each cut
    /// edge resolves its destination in the owner's graph — an invariant
    /// of source-routing (`AddEdge` notifies the destination owner), so
    /// an unresolvable destination is a routing bug and panics in debug.
    pub fn build(graphs: &[&DynamicGraph], parts: &Partitioner) -> Self {
        let k = graphs.len();
        assert_eq!(k, parts.shards(), "one graph per shard");
        let mut owned = vec![Vec::new(); k];
        let mut inv_out = Vec::with_capacity(k);
        let mut internal = vec![Vec::new(); k];
        let mut cross = vec![vec![Vec::new(); k]; k];
        let mut len = Vec::with_capacity(k);
        let mut n_total = 0usize;
        let mut cut_edges = 0usize;
        for (s, g) in graphs.iter().enumerate() {
            let n = g.num_vertices();
            len.push(n);
            let mut inv = vec![0.0f64; n];
            for u in 0..n as VertexIdx {
                if parts.shard_of(g.id(u)) != s {
                    continue; // ghost: no out-edges, not owned here
                }
                owned[s].push(u);
                n_total += 1;
                let d = g.out_degree(u);
                if d > 0 {
                    inv[u as usize] = 1.0 / d as f64;
                }
                for &v in g.out_neighbors(u) {
                    let vid = g.id(v);
                    let t = parts.shard_of(vid);
                    if t == s {
                        internal[s].push((u, v));
                    } else {
                        let dst_local = graphs[t]
                            .index(vid)
                            .expect("cut-edge destination unknown to its owner shard");
                        cross[s][t].push((u, dst_local));
                        cut_edges += 1;
                    }
                }
            }
            inv_out.push(inv);
        }
        Self { owned, inv_out, internal, cross, len, n_total, cut_edges }
    }

    /// Union of owned vertices across shards (the single-engine `|V|`).
    pub fn total_vertices(&self) -> usize {
        self.n_total
    }

    /// Cut edges crossing shard boundaries.
    pub fn cut_edges(&self) -> usize {
        self.cut_edges
    }

    /// Owned-vertex count of one shard.
    pub fn owned_in(&self, shard: usize) -> usize {
        self.owned[shard].len()
    }
}

/// Result of one exchange run: per-shard rank vectors in local dense
/// order (ghost slots untouched), plus the usual power-method telemetry.
#[derive(Clone, Debug)]
pub struct ExchangeResult {
    /// Rank per shard, indexed by local dense index.
    pub ranks: Vec<Vec<f64>>,
    /// Iterations executed (global — shards iterate in lockstep).
    pub iterations: usize,
    /// Global L1 delta of the final iteration.
    pub last_delta: f64,
}

/// Run the boundary-exchange power method over a frozen [`ShardPlan`].
///
/// `warm` seeds per-shard rank vectors (local dense order); shards whose
/// vector is missing or mis-sized fall back to the uniform init — the
/// same warm-start contract as [`crate::pagerank::power::PageRank`]'s
/// `run_from`, degraded per shard instead of panicking because shard
/// graphs can grow independently between recomputes.
pub fn run_exchange(
    plan: &ShardPlan,
    cfg: &PageRankConfig,
    warm: Option<Vec<Vec<f64>>>,
) -> ExchangeResult {
    let k = plan.len.len();
    let n = plan.n_total;
    if n == 0 {
        return ExchangeResult {
            ranks: plan.len.iter().map(|&l| vec![0.0; l]).collect(),
            iterations: 0,
            last_delta: 0.0,
        };
    }
    let teleport = cfg.teleport(n);
    let epsilon = cfg.scaled_epsilon(n);
    let init = cfg.init_rank(n);
    let mut warm = warm.unwrap_or_default();
    warm.resize(k, Vec::new());
    let mut ranks: Vec<Vec<f64>> = warm
        .into_iter()
        .zip(&plan.len)
        .map(|(w, &l)| if w.len() == l { w } else { vec![init; l] })
        .collect();
    let mut next: Vec<Vec<f64>> = plan.len.iter().map(|&l| vec![0.0; l]).collect();
    let mut contrib: Vec<Vec<f64>> = plan.len.iter().map(|&l| vec![0.0; l]).collect();
    // One inbox per destination shard, refilled every iteration — the
    // remote-shard-as-big-vertex aggregate.
    let mut inbox: Vec<RemoteAggregate> =
        plan.len.iter().map(|&l| RemoteAggregate::new(l)).collect();
    let mut iterations = 0;
    let mut last_delta = f64::INFINITY;
    for _ in 0..cfg.max_iters {
        // Scatter: scale each owned source once (r_u / d_out(u)).
        for s in 0..k {
            let (c, r, inv) = (&mut contrib[s], &ranks[s], &plan.inv_out[s]);
            for &u in &plan.owned[s] {
                c[u as usize] = r[u as usize] * inv[u as usize];
            }
        }
        // Dangling mass is global: owned vertices with no out-edges leak
        // rank the redistribution hands back to every vertex.
        let dangling_share = if cfg.dangling_redistribution {
            let mut mass = 0.0;
            for s in 0..k {
                for &u in &plan.owned[s] {
                    if plan.inv_out[s][u as usize] == 0.0 {
                        mass += ranks[s][u as usize];
                    }
                }
            }
            cfg.beta * mass / n as f64
        } else {
            0.0
        };
        // Gather: local edges accumulate directly; cut edges go through
        // the destination shard's inbox.
        for (s, nx) in next.iter_mut().enumerate() {
            nx.iter_mut().for_each(|x| *x = 0.0);
            for &(u, v) in &plan.internal[s] {
                nx[v as usize] += contrib[s][u as usize];
            }
        }
        for s in 0..k {
            for (t, edges) in plan.cross[s].iter().enumerate() {
                for &(u, v) in edges {
                    inbox[t].add(v, contrib[s][u as usize]);
                }
            }
        }
        // Apply + fold the exchanged boundary mass; per-shard L1 deltas
        // reduce in shard order (deterministic for a fixed shard count).
        let mut delta = 0.0;
        for s in 0..k {
            let (nx, r, inb) = (&mut next[s], &ranks[s], &inbox[s]);
            for &v in &plan.owned[s] {
                let x = teleport + cfg.beta * (nx[v as usize] + inb.b()[v as usize])
                    + dangling_share;
                delta += (x - r[v as usize]).abs();
                nx[v as usize] = x;
            }
            inbox[s].clear();
        }
        iterations += 1;
        last_delta = delta;
        std::mem::swap(&mut ranks, &mut next);
        if cfg.epsilon > 0.0 && last_delta < epsilon {
            break;
        }
    }
    ExchangeResult { ranks, iterations, last_delta }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pagerank::power::PageRank;
    use crate::stream::event::EdgeOp;

    /// Build per-shard graphs by routing edge ops, plus the matching
    /// single-engine graph.
    fn build_sharded(
        edges: &[(u64, u64)],
        shards: usize,
    ) -> (Vec<DynamicGraph>, DynamicGraph, Partitioner) {
        let parts = Partitioner::new(shards);
        let ops: Vec<EdgeOp> = edges.iter().map(|&(s, d)| EdgeOp::AddEdge(s, d)).collect();
        let routed = parts.route(&ops);
        let mut graphs: Vec<DynamicGraph> = (0..shards).map(|_| DynamicGraph::new()).collect();
        for (g, ops) in graphs.iter_mut().zip(&routed) {
            g.apply_batch(ops, None, 1);
        }
        let (single, _) = DynamicGraph::from_edges(edges.to_vec());
        (graphs, single, parts)
    }

    #[test]
    fn exchange_matches_single_engine_on_a_ring() {
        let edges: Vec<(u64, u64)> = (0..20u64).map(|i| (i, (i + 1) % 20)).collect();
        for shards in [1usize, 2, 4] {
            let (graphs, single, parts) = build_sharded(&edges, shards);
            let refs: Vec<&DynamicGraph> = graphs.iter().collect();
            let plan = ShardPlan::build(&refs, &parts);
            assert_eq!(plan.total_vertices(), single.num_vertices());
            let cfg = PageRankConfig::default();
            let ex = run_exchange(&plan, &cfg, None);
            let exact = PageRank::new(cfg).run(&single.snapshot());
            let mut l1 = 0.0;
            for (s, g) in graphs.iter().enumerate() {
                for u in 0..g.num_vertices() as VertexIdx {
                    let id = g.id(u);
                    if parts.shard_of(id) != s {
                        continue;
                    }
                    let idx = single.index(id).unwrap();
                    l1 += (ex.ranks[s][u as usize] - exact.ranks[idx as usize]).abs();
                }
            }
            assert!(l1 < 1e-6, "shards={shards}: L1={l1}");
        }
    }

    #[test]
    fn empty_plan_is_a_no_op() {
        let parts = Partitioner::new(2);
        let graphs = [DynamicGraph::new(), DynamicGraph::new()];
        let refs: Vec<&DynamicGraph> = graphs.iter().collect();
        let plan = ShardPlan::build(&refs, &parts);
        let ex = run_exchange(&plan, &PageRankConfig::default(), None);
        assert_eq!(ex.iterations, 0);
        assert!(ex.ranks.iter().all(Vec::is_empty));
    }
}
