//! Cross-shard PageRank with a boundary-rank exchange step per
//! iteration.
//!
//! Each shard owns the out-edges of its vertices (source-routed
//! partition, [`crate::graph::partition::Partitioner`]). One global
//! power-method iteration becomes three per-shard half-steps:
//!
//! 1. **Scatter** — scale every owned source once: `c_u = r_u /
//!    d_out(u)` (`d_out` is exact because all of `u`'s out-edges live on
//!    its owner), and partial-sum the dangling mass over the plan's
//!    precomputed dangling list.
//! 2. **Gather** — per *destination* shard: internal edges accumulate
//!    `c_u` directly into the gather slots; cut edges fold into the
//!    destination's [`RemoteAggregate`] inbox (the remote shard is
//!    "just another big vertex": per-target rolled-up boundary mass,
//!    exactly the `b_z` shape of `summary/bigvertex.rs`, except
//!    re-exchanged every iteration instead of frozen once). Source
//!    shards are visited in shard order, so every slot sums its in-mass
//!    in one fixed order.
//! 3. **Apply** — `next_v = teleport + β·(local_v + inbox_v) [+
//!    dangling]` for owned `v`, zeroing each touched gather slot on the
//!    way out (the hoisted zero-fill: untouched slots are already zero,
//!    so no per-iteration `memset` remains); per-shard L1 deltas reduce
//!    in shard order into the global convergence test.
//!
//! Each half-step writes one shard's state only, so
//! [`run_exchange_pooled`] fans the shards out on a [`ThreadPool`] via
//! `scope_chunks`, with the boundary-inbox exchange and the
//! dangling-mass / L1 reductions as the only synchronization points.
//! Per-shard partials come back in shard order and fold left-to-right
//! whether the phases ran inline or pooled, so the pooled exchange is
//! **bit-identical** to the serial one at every worker count
//! (property-tested for 1, 2, 4 and 7 workers).
//!
//! Every owned vertex receives exactly the contributions the
//! single-engine gather sums for it, under the same teleport, init,
//! dangling and `scaled_epsilon(n_total)` semantics
//! ([`crate::pagerank::power`]) — so the exchange converges to the same
//! fixed point. Floating-point summation *order* differs (a vertex's
//! in-mass splits into local + per-shard inbox partial sums), which is
//! why sharded-vs-single equivalence is stated as a tolerance
//! (`L1 < 1e-6` in the property tests), not bit-identity.

use crate::graph::dynamic::DynamicGraph;
use crate::graph::partition::Partitioner;
use crate::graph::VertexIdx;
use crate::pagerank::power::PageRankConfig;
use crate::summary::bigvertex::RemoteAggregate;
use crate::util::threadpool::ThreadPool;

/// The frozen exchange topology for one recompute: per-shard internal
/// edge lists plus cut-edge lists pre-resolved to *destination-local*
/// indices, so the iteration loop never touches an id map.
///
/// Plans are rebuildable per shard ([`ShardPlan::rebuild_shards`]):
/// only the shards whose graph moved are re-derived, which is sound
/// because [`DynamicGraph`] never reuses or shifts dense indices (adds
/// append, removals keep the slot) — a clean shard's cached
/// destination-local indices into a rebuilt shard stay valid.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// Per shard: local indices of the vertices it owns (ghosts skipped).
    owned: Vec<Vec<VertexIdx>>,
    /// Per shard: `1/d_out` per local index (0 for dangling and ghosts).
    inv_out: Vec<Vec<f64>>,
    /// Per shard: owned vertices with no out-edges, in owned order — the
    /// per-iteration dangling-mass pass reads this list instead of
    /// re-scanning every owned vertex's `inv_out`.
    dangling: Vec<Vec<VertexIdx>>,
    /// Per shard: internal edges `(src_local, dst_local)`.
    internal: Vec<Vec<(VertexIdx, VertexIdx)>>,
    /// `cross[s][t]`: cut edges from shard `s` into shard `t`, as
    /// `(src_local_in_s, dst_local_in_t)`.
    cross: Vec<Vec<Vec<(VertexIdx, VertexIdx)>>>,
    /// Per shard: local vector length (`graph.num_vertices()`, ghosts
    /// included).
    len: Vec<usize>,
    /// Union of owned vertices — the single-engine `|V|`.
    n_total: usize,
    /// Total cut edges (boundary edges between shards).
    cut_edges: usize,
}

/// One shard's freshly derived slice of a plan.
struct ShardTopo {
    owned: Vec<VertexIdx>,
    inv_out: Vec<f64>,
    dangling: Vec<VertexIdx>,
    internal: Vec<(VertexIdx, VertexIdx)>,
    /// Cut edges out of this shard, per destination shard.
    cross_out: Vec<Vec<(VertexIdx, VertexIdx)>>,
    len: usize,
}

/// Derive one shard's topology slice. Ownership is re-derived from the
/// partitioner (ghosts are skipped), and each cut edge resolves its
/// destination in the owner's graph — an invariant of source-routing
/// (`AddEdge` notifies the destination owner), so an unresolvable
/// destination is a routing bug and panics.
fn build_shard(s: usize, graphs: &[&DynamicGraph], parts: &Partitioner) -> ShardTopo {
    let k = graphs.len();
    let g = graphs[s];
    let n = g.num_vertices();
    let mut topo = ShardTopo {
        owned: Vec::new(),
        inv_out: vec![0.0f64; n],
        dangling: Vec::new(),
        internal: Vec::new(),
        cross_out: vec![Vec::new(); k],
        len: n,
    };
    for u in 0..n as VertexIdx {
        if parts.shard_of(g.id(u)) != s {
            continue; // ghost: no out-edges, not owned here
        }
        topo.owned.push(u);
        let d = g.out_degree(u);
        if d > 0 {
            topo.inv_out[u as usize] = 1.0 / d as f64;
        } else {
            topo.dangling.push(u);
        }
        for &v in g.out_neighbors(u) {
            let vid = g.id(v);
            let t = parts.shard_of(vid);
            if t == s {
                topo.internal.push((u, v));
            } else {
                let dst_local = graphs[t]
                    .index(vid)
                    .expect("cut-edge destination unknown to its owner shard");
                topo.cross_out[t].push((u, dst_local));
            }
        }
    }
    topo
}

impl ShardPlan {
    /// Freeze the exchange topology from per-shard graphs.
    pub fn build(graphs: &[&DynamicGraph], parts: &Partitioner) -> Self {
        let k = graphs.len();
        assert_eq!(k, parts.shards(), "one graph per shard");
        let mut plan = Self {
            owned: vec![Vec::new(); k],
            inv_out: vec![Vec::new(); k],
            dangling: vec![Vec::new(); k],
            internal: vec![Vec::new(); k],
            cross: vec![Vec::new(); k],
            len: vec![0; k],
            n_total: 0,
            cut_edges: 0,
        };
        for s in 0..k {
            plan.install_shard(s, build_shard(s, graphs, parts));
        }
        plan.refresh_totals();
        plan
    }

    /// Re-derive the topology of exactly the `dirty` shards, keeping
    /// every clean shard's slice — including its cut-edge lists into
    /// rebuilt shards, whose destination-local indices are append-stable
    /// by the [`DynamicGraph`] index contract. The cluster-wide
    /// aggregates are refreshed from the merged state.
    pub fn rebuild_shards(
        &mut self,
        graphs: &[&DynamicGraph],
        parts: &Partitioner,
        dirty: &[bool],
    ) {
        let k = self.len.len();
        assert_eq!(graphs.len(), k, "one graph per shard");
        assert_eq!(dirty.len(), k, "one dirty flag per shard");
        for (s, &moved) in dirty.iter().enumerate() {
            if moved {
                self.install_shard(s, build_shard(s, graphs, parts));
            }
        }
        self.refresh_totals();
    }

    fn install_shard(&mut self, s: usize, topo: ShardTopo) {
        self.owned[s] = topo.owned;
        self.inv_out[s] = topo.inv_out;
        self.dangling[s] = topo.dangling;
        self.internal[s] = topo.internal;
        self.cross[s] = topo.cross_out;
        self.len[s] = topo.len;
    }

    fn refresh_totals(&mut self) {
        self.n_total = self.owned.iter().map(|o| o.len()).sum();
        self.cut_edges = self.cross.iter().flat_map(|row| row.iter().map(Vec::len)).sum();
    }

    /// Union of owned vertices across shards (the single-engine `|V|`).
    pub fn total_vertices(&self) -> usize {
        self.n_total
    }

    /// Cut edges crossing shard boundaries.
    pub fn cut_edges(&self) -> usize {
        self.cut_edges
    }

    /// Owned-vertex count of one shard.
    pub fn owned_in(&self, shard: usize) -> usize {
        self.owned[shard].len()
    }

    /// Number of shards the plan spans.
    pub fn shards(&self) -> usize {
        self.len.len()
    }
}

/// Reusable per-shard exchange buffers: the scatter contributions, the
/// gather slots, the next-rank vectors and the [`RemoteAggregate`]
/// inboxes. Owned by the caller (the sharded engine keeps one, like its
/// `SummaryScratch`) so repeated recomputes reuse the allocations
/// instead of rebuilding them per run; [`run_exchange_pooled`] sizes and
/// zeroes everything it needs on entry, so a scratch can move freely
/// between plans of different shapes.
#[derive(Debug, Default)]
pub struct ExchangeScratch {
    /// Per shard: `r_u / d_out(u)` per local index, rewritten each
    /// iteration.
    contrib: Vec<Vec<f64>>,
    slots: Vec<ShardSlot>,
}

/// One shard's mutable half of an iteration — everything the gather and
/// apply phases write, grouped so the pool can hand each shard's slot to
/// exactly one worker.
#[derive(Debug, Default)]
struct ShardSlot {
    /// Local-gather accumulator; zero outside the apply phase.
    acc: Vec<f64>,
    /// The rank vector under construction this iteration.
    next: Vec<f64>,
    /// Boundary mass exchanged into this shard.
    inbox: RemoteAggregate,
}

impl ExchangeScratch {
    /// An empty scratch; buffers materialize on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// Size every buffer for `plan`, zeroing carried values. Reuse keeps
    /// the allocations; only a grown shard reallocates.
    fn ensure(&mut self, plan: &ShardPlan) {
        let k = plan.len.len();
        self.contrib.resize_with(k, Vec::new);
        self.slots.resize_with(k, ShardSlot::default);
        for (s, &l) in plan.len.iter().enumerate() {
            let c = &mut self.contrib[s];
            c.clear();
            c.resize(l, 0.0);
            let slot = &mut self.slots[s];
            slot.acc.clear();
            slot.acc.resize(l, 0.0);
            slot.next.clear();
            slot.next.resize(l, 0.0);
            slot.inbox.reset(l);
        }
    }
}

/// Result of one exchange run: per-shard rank vectors in local dense
/// order (ghost slots are never published), plus the usual power-method
/// telemetry.
#[derive(Clone, Debug)]
pub struct ExchangeResult {
    /// Rank per shard, indexed by local dense index.
    pub ranks: Vec<Vec<f64>>,
    /// Iterations executed (global — shards iterate in lockstep).
    pub iterations: usize,
    /// Global L1 delta of the final iteration.
    pub last_delta: f64,
}

/// Run `f` once per shard: inline in shard order without a pool, fanned
/// out via `scope_chunks` over one-element chunks with one. Results come
/// back in shard order either way, so reductions folded over the
/// returned vector are bit-identical at every worker count.
fn dispatch<T, R, F>(pool: Option<&ThreadPool>, data: &mut [T], f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    match pool {
        Some(pool) if data.len() > 1 => {
            let cuts: Vec<usize> = (0..=data.len()).collect();
            pool.scope_chunks(data, &cuts, |s, chunk| f(s, &mut chunk[0]))
        }
        _ => data.iter_mut().enumerate().map(|(s, x)| f(s, x)).collect(),
    }
}

/// Run the boundary-exchange power method over a frozen [`ShardPlan`],
/// serially and with one-shot scratch buffers. Equivalent to
/// [`run_exchange_pooled`] with no pool — kept as the simple entry point
/// for tests and one-off runs.
pub fn run_exchange(
    plan: &ShardPlan,
    cfg: &PageRankConfig,
    warm: Option<Vec<Vec<f64>>>,
) -> ExchangeResult {
    run_exchange_pooled(plan, cfg, warm, None, &mut ExchangeScratch::new())
}

/// Run the boundary-exchange power method over a frozen [`ShardPlan`],
/// fanning the per-shard half-steps of each iteration out on `pool`
/// (inline in shard order when `pool` is `None` — same code path, same
/// floats) and reusing `scratch` buffers across calls.
///
/// `warm` seeds per-shard rank vectors (local dense order); shards whose
/// vector is missing or mis-sized fall back to the uniform init — the
/// same warm-start contract as [`crate::pagerank::power::PageRank`]'s
/// `run_from`, degraded per shard instead of panicking because shard
/// graphs can grow independently between recomputes.
pub fn run_exchange_pooled(
    plan: &ShardPlan,
    cfg: &PageRankConfig,
    warm: Option<Vec<Vec<f64>>>,
    pool: Option<&ThreadPool>,
    scratch: &mut ExchangeScratch,
) -> ExchangeResult {
    let n = plan.n_total;
    if n == 0 {
        return ExchangeResult {
            ranks: plan.len.iter().map(|&l| vec![0.0; l]).collect(),
            iterations: 0,
            last_delta: 0.0,
        };
    }
    let k = plan.len.len();
    let teleport = cfg.teleport(n);
    let epsilon = cfg.scaled_epsilon(n);
    let init = cfg.init_rank(n);
    let mut warm = warm.unwrap_or_default();
    warm.resize(k, Vec::new());
    let mut ranks: Vec<Vec<f64>> = warm
        .into_iter()
        .zip(&plan.len)
        .map(|(w, &l)| if w.len() == l { w } else { vec![init; l] })
        .collect();
    scratch.ensure(plan);
    let ExchangeScratch { contrib, slots } = scratch;
    let mut iterations = 0;
    let mut last_delta = f64::INFINITY;
    for _ in 0..cfg.max_iters {
        // Scatter (parallel per source shard): scale each owned source
        // once (r_u / d_out(u)) and partial-sum the dangling mass over
        // the plan's precomputed dangling list.
        let r_now = &ranks;
        let masses = dispatch(pool, contrib, |s, c| {
            let (r, inv) = (&r_now[s], &plan.inv_out[s]);
            for &u in &plan.owned[s] {
                c[u as usize] = r[u as usize] * inv[u as usize];
            }
            if cfg.dangling_redistribution {
                plan.dangling[s].iter().map(|&u| r[u as usize]).sum()
            } else {
                0.0
            }
        });
        // Dangling mass is global: the per-shard partials fold in shard
        // order, so the share is the same float at every worker count.
        let dangling_share = if cfg.dangling_redistribution {
            cfg.beta * masses.iter().sum::<f64>() / n as f64
        } else {
            0.0
        };
        // Gather (parallel per destination shard): internal edges
        // accumulate into the gather slots; cut edges fold into the
        // inbox, source shards visited in shard order so every slot sums
        // its in-mass in the serial order.
        let c_now: &[Vec<f64>] = contrib;
        dispatch(pool, slots, |t, slot| {
            let c = &c_now[t];
            for &(u, v) in &plan.internal[t] {
                slot.acc[v as usize] += c[u as usize];
            }
            for (src, c) in c_now.iter().enumerate() {
                for &(u, v) in &plan.cross[src][t] {
                    slot.inbox.add(v, c[u as usize]);
                }
            }
        });
        // Apply (parallel per shard): fold gather + inbox under the
        // shared teleport/dangling terms, partial-sum the L1 delta, and
        // zero each touched gather slot for the next iteration (edges
        // only ever target owned vertices, so this sweep restores the
        // all-zero invariant).
        let deltas = dispatch(pool, slots, |s, slot| {
            let ShardSlot { acc, next, inbox } = slot;
            let r = &r_now[s];
            let b = inbox.b();
            let mut delta = 0.0;
            for &v in &plan.owned[s] {
                let vi = v as usize;
                let x = teleport + cfg.beta * (acc[vi] + b[vi]) + dangling_share;
                delta += (x - r[vi]).abs();
                next[vi] = x;
                acc[vi] = 0.0;
            }
            inbox.clear();
            delta
        });
        // Per-shard L1 partials reduce in shard order (deterministic for
        // a fixed shard count at any worker count).
        last_delta = deltas.iter().sum();
        iterations += 1;
        for (r, slot) in ranks.iter_mut().zip(slots.iter_mut()) {
            std::mem::swap(r, &mut slot.next);
        }
        if cfg.epsilon > 0.0 && last_delta < epsilon {
            break;
        }
    }
    ExchangeResult { ranks, iterations, last_delta }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pagerank::power::PageRank;
    use crate::stream::event::EdgeOp;
    use crate::testing::vprop::{forall, Gen};

    /// Build per-shard graphs by routing edge ops, plus the matching
    /// single-engine graph.
    fn build_sharded(
        edges: &[(u64, u64)],
        shards: usize,
    ) -> (Vec<DynamicGraph>, DynamicGraph, Partitioner) {
        let parts = Partitioner::new(shards);
        let ops: Vec<EdgeOp> = edges.iter().map(|&(s, d)| EdgeOp::AddEdge(s, d)).collect();
        let routed = parts.route(&ops);
        let mut graphs: Vec<DynamicGraph> = (0..shards).map(|_| DynamicGraph::new()).collect();
        for (g, ops) in graphs.iter_mut().zip(&routed) {
            g.apply_batch(ops, None, 1);
        }
        let (single, _) = DynamicGraph::from_edges(edges.to_vec());
        (graphs, single, parts)
    }

    /// Exact bit pattern of an exchange result, for bit-identity
    /// assertions.
    fn bits(r: &ExchangeResult) -> (usize, u64, Vec<Vec<u64>>) {
        (
            r.iterations,
            r.last_delta.to_bits(),
            r.ranks.iter().map(|v| v.iter().map(|x| x.to_bits()).collect()).collect(),
        )
    }

    #[test]
    fn exchange_matches_single_engine_on_a_ring() {
        let edges: Vec<(u64, u64)> = (0..20u64).map(|i| (i, (i + 1) % 20)).collect();
        for shards in [1usize, 2, 4] {
            let (graphs, single, parts) = build_sharded(&edges, shards);
            let refs: Vec<&DynamicGraph> = graphs.iter().collect();
            let plan = ShardPlan::build(&refs, &parts);
            assert_eq!(plan.total_vertices(), single.num_vertices());
            let cfg = PageRankConfig::default();
            let ex = run_exchange(&plan, &cfg, None);
            let exact = PageRank::new(cfg).run(&single.snapshot());
            let mut l1 = 0.0;
            for (s, g) in graphs.iter().enumerate() {
                for u in 0..g.num_vertices() as VertexIdx {
                    let id = g.id(u);
                    if parts.shard_of(id) != s {
                        continue;
                    }
                    let idx = single.index(id).unwrap();
                    l1 += (ex.ranks[s][u as usize] - exact.ranks[idx as usize]).abs();
                }
            }
            assert!(l1 < 1e-6, "shards={shards}: L1={l1}");
        }
    }

    #[test]
    fn empty_plan_is_a_no_op() {
        let parts = Partitioner::new(2);
        let graphs = [DynamicGraph::new(), DynamicGraph::new()];
        let refs: Vec<&DynamicGraph> = graphs.iter().collect();
        let plan = ShardPlan::build(&refs, &parts);
        let ex = run_exchange(&plan, &PageRankConfig::default(), None);
        assert_eq!(ex.iterations, 0);
        assert!(ex.ranks.iter().all(Vec::is_empty));
    }

    /// Property (the tentpole acceptance): the pooled exchange returns
    /// the exact bits of the serial exchange for every tested worker
    /// count, cold- and warm-started, on arbitrary random topologies —
    /// including with a scratch reused across runs.
    #[test]
    fn pooled_exchange_is_bit_identical_to_serial() {
        forall(8, 0xB17F0, |g: &mut Gen| {
            let shards = g.usize(1..5);
            let n = g.usize(2..24);
            let m = g.usize(0..48);
            let mut edges = g.edges(n, m);
            if g.bool(0.5) {
                edges.extend((0..n as u64).map(|i| (i, (i + 1) % n as u64)));
            }
            let (graphs, _, parts) = build_sharded(&edges, shards);
            let refs: Vec<&DynamicGraph> = graphs.iter().collect();
            let plan = ShardPlan::build(&refs, &parts);
            let cfg = PageRankConfig::default();
            let serial = run_exchange(&plan, &cfg, None);
            let warm = serial.ranks.clone();
            let serial_warm = run_exchange(&plan, &cfg, Some(warm.clone()));
            let mut scratch = ExchangeScratch::new();
            for workers in [1usize, 2, 4, 7] {
                let pool = ThreadPool::new(workers);
                let pooled = run_exchange_pooled(&plan, &cfg, None, Some(&pool), &mut scratch);
                assert_eq!(bits(&serial), bits(&pooled), "cold, workers={workers}");
                let pooled_warm = run_exchange_pooled(
                    &plan,
                    &cfg,
                    Some(warm.clone()),
                    Some(&pool),
                    &mut scratch,
                );
                assert_eq!(bits(&serial_warm), bits(&pooled_warm), "warm, workers={workers}");
            }
        });
    }

    /// The degenerate shapes the pooled dispatch must not trip over:
    /// an empty cluster, an all-dangling graph (the dangling reduction
    /// carries all the mass) and a single shard (one chunk runs inline).
    /// One scratch moves across all three plans, exercising the
    /// resize-and-rezero path.
    #[test]
    fn pooled_exchange_handles_degenerate_shapes() {
        let cfg = PageRankConfig::default();
        for workers in [1usize, 2, 4, 7] {
            let pool = ThreadPool::new(workers);
            let mut scratch = ExchangeScratch::new();

            let parts = Partitioner::new(2);
            let graphs = [DynamicGraph::new(), DynamicGraph::new()];
            let refs: Vec<&DynamicGraph> = graphs.iter().collect();
            let plan = ShardPlan::build(&refs, &parts);
            let pooled = run_exchange_pooled(&plan, &cfg, None, Some(&pool), &mut scratch);
            assert_eq!(pooled.iterations, 0, "empty cluster is a no-op");

            let parts = Partitioner::new(3);
            let ops: Vec<EdgeOp> = (0..12u64).map(EdgeOp::AddVertex).collect();
            let routed = parts.route(&ops);
            let mut graphs: Vec<DynamicGraph> = (0..3).map(|_| DynamicGraph::new()).collect();
            for (g, ops) in graphs.iter_mut().zip(&routed) {
                g.apply_batch(ops, None, 1);
            }
            let refs: Vec<&DynamicGraph> = graphs.iter().collect();
            let plan = ShardPlan::build(&refs, &parts);
            let serial = run_exchange(&plan, &cfg, None);
            let pooled = run_exchange_pooled(&plan, &cfg, None, Some(&pool), &mut scratch);
            assert_eq!(bits(&serial), bits(&pooled), "all-dangling, workers={workers}");

            let (graphs, _, parts) = build_sharded(&[(0, 1), (1, 2), (2, 0), (3, 1)], 1);
            let refs: Vec<&DynamicGraph> = graphs.iter().collect();
            let plan = ShardPlan::build(&refs, &parts);
            let serial = run_exchange(&plan, &cfg, None);
            let pooled = run_exchange_pooled(&plan, &cfg, None, Some(&pool), &mut scratch);
            assert_eq!(bits(&serial), bits(&pooled), "single-shard, workers={workers}");
        }
    }

    /// Property: incrementally rebuilding only the shards whose graph
    /// version moved reproduces a from-scratch `ShardPlan::build` under
    /// arbitrary mutation interleavings — checked through the exchange
    /// output bits, the vertex union and the cut-edge count.
    #[test]
    fn incremental_plan_rebuild_matches_fresh_build() {
        forall(10, 0x9AB5, |g: &mut Gen| {
            let shards = g.usize(1..5);
            let parts = Partitioner::new(shards);
            let n = g.usize(4..16) as u64;
            let initial: Vec<EdgeOp> =
                g.edges(n as usize, 16).into_iter().map(|(s, d)| EdgeOp::add(s, d)).collect();
            let apply = |graphs: &mut Vec<DynamicGraph>, ops: &[EdgeOp]| {
                for (sg, ops) in graphs.iter_mut().zip(&parts.route(ops)) {
                    sg.apply_batch(ops, None, 1);
                }
            };
            let mut graphs: Vec<DynamicGraph> = (0..shards).map(|_| DynamicGraph::new()).collect();
            apply(&mut graphs, &initial);
            let refs: Vec<&DynamicGraph> = graphs.iter().collect();
            let mut cached = ShardPlan::build(&refs, &parts);
            let mut versions: Vec<u64> = graphs.iter().map(DynamicGraph::version).collect();
            for _ in 0..g.usize(1..5) {
                let mut batch = Vec::new();
                for _ in 0..g.usize(1..8) {
                    let (a, b) = (g.u64(0..n + 4), g.u64(0..n + 4));
                    if a == b {
                        continue;
                    }
                    batch.push(if g.bool(0.1) {
                        EdgeOp::RemoveVertex(a)
                    } else if g.bool(0.3) {
                        EdgeOp::remove(a, b)
                    } else {
                        EdgeOp::add(a, b)
                    });
                }
                apply(&mut graphs, &batch);
                let now: Vec<u64> = graphs.iter().map(DynamicGraph::version).collect();
                let dirty: Vec<bool> = versions.iter().zip(&now).map(|(a, b)| a != b).collect();
                versions = now;
                let refs: Vec<&DynamicGraph> = graphs.iter().collect();
                cached.rebuild_shards(&refs, &parts, &dirty);
                let fresh = ShardPlan::build(&refs, &parts);
                assert_eq!(cached.total_vertices(), fresh.total_vertices());
                assert_eq!(cached.cut_edges(), fresh.cut_edges());
                let cfg = PageRankConfig::default();
                let a = run_exchange(&cached, &cfg, None);
                let b = run_exchange(&fresh, &cfg, None);
                assert_eq!(bits(&a), bits(&b), "rebuilt plan diverges from fresh build");
            }
        });
    }
}
