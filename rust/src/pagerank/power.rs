//! Exact PageRank via the power method over a CSR snapshot.
//!
//! This is the paper's ground-truth baseline (§2, §5): the vertex-centric
//! normalized power iteration
//!
//! ```text
//! r'_v = (1-β)/n + β · Σ_{(u,v) ∈ E} r_u / d_out(u)
//! ```
//!
//! matching Flink Gelly semantics — mass flowing into dangling vertices
//! simply leaves the system unless `dangling_redistribution` is enabled
//! (ablated in tests; the paper's baseline does not redistribute).
//!
//! Two execution strategies share the same numerics: the serial loop
//! ([`PageRank::run`]/[`PageRank::run_from`]) and a sharded parallel
//! variant ([`PageRank::run_parallel`]) that splits the destination-vertex
//! range into in-edge-balanced shards ([`Csr::shards`]) and runs each
//! iteration's gather across a [`ThreadPool`]. Every vertex's in-edge sum
//! is accumulated in the identical order either way, so parallel ranks
//! are bit-identical to serial ranks for any shard count; only the L1
//! convergence delta is reduced per-shard (in shard order — deterministic
//! for a fixed `parallelism`).

use crate::graph::csr::Csr;
use crate::util::threadpool::ThreadPool;

/// PageRank configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PageRankConfig {
    /// Damping factor β (paper's notation; 0.85 is the classic choice).
    pub beta: f64,
    /// Iteration cap.
    pub max_iters: usize,
    /// L1 convergence threshold; 0 disables early exit.
    pub epsilon: f64,
    /// Redistribute dangling mass uniformly (off = Gelly semantics).
    pub dangling_redistribution: bool,
    /// `true` → probability-normalized variant (init 1/n, teleport
    /// (1-β)/n, ranks sum ≈ 1). `false` (default, Gelly/paper semantics)
    /// → unnormalized variant (init 1, teleport (1-β), ranks ~O(1)).
    /// The unnormalized scale is what calibrates Eq. 5's `f_Δ`.
    pub normalized: bool,
    /// Warm-start exact recomputations from the previous rank vector.
    /// `false` reproduces the paper's baseline — a *complete* PageRank
    /// execution from the uniform init on every exact query (§5: “the
    /// complete PageRank is executed for all Q queries”). `true` is this
    /// implementation's extra optimization (kept off for ground-truth
    /// runs so speedups are measured against the paper's own baseline;
    /// the warm-started baseline is reported separately in ablation A7).
    pub warm_start_exact: bool,
    /// Shard count for the parallel executors ([`PageRank::run_parallel`]
    /// and `pagerank::summarized::run_summarized_parallel`): `1` (the
    /// default) = serial, `0` = one shard per pool worker, `k > 1` =
    /// exactly `k` degree-balanced shards. Results are deterministic for
    /// a fixed shard count — per-vertex sums run in the serial order and
    /// the L1-delta reduction is per-shard then in shard order.
    pub parallelism: usize,
}

impl Default for PageRankConfig {
    fn default() -> Self {
        Self {
            beta: 0.85,
            max_iters: 100,
            epsilon: 1e-9,
            dangling_redistribution: false,
            normalized: false,
            warm_start_exact: true,
            parallelism: 1,
        }
    }
}

impl PageRankConfig {
    /// Teleport term added to every vertex each iteration.
    pub fn teleport(&self, n: usize) -> f64 {
        if self.normalized {
            (1.0 - self.beta) / n.max(1) as f64
        } else {
            1.0 - self.beta
        }
    }

    /// Initial (and new-vertex default) rank.
    pub fn init_rank(&self, n: usize) -> f64 {
        if self.normalized {
            1.0 / n.max(1) as f64
        } else {
            1.0
        }
    }

    /// Convergence epsilon scaled to the variant's magnitude: the
    /// unnormalized variant's L1 deltas are ~n× larger, so `epsilon`
    /// is interpreted per-vertex and multiplied by n here.
    pub fn scaled_epsilon(&self, n: usize) -> f64 {
        if self.normalized {
            self.epsilon
        } else {
            self.epsilon * n.max(1) as f64
        }
    }

    /// Resolve the `parallelism` knob against a pool: `0` = one shard per
    /// worker, otherwise the exact configured count.
    pub fn effective_shards(&self, pool: &ThreadPool) -> usize {
        if self.parallelism == 0 {
            pool.size()
        } else {
            self.parallelism
        }
    }
}

/// Result of a power-method run.
#[derive(Clone, Debug)]
pub struct PageRankResult {
    /// Final rank per dense vertex index.
    pub ranks: Vec<f64>,
    /// Iterations actually executed.
    pub iterations: usize,
    /// L1 delta of the final iteration.
    pub last_delta: f64,
}

/// Power-method PageRank runner.
#[derive(Clone, Debug, Default)]
pub struct PageRank {
    /// Configuration used for every run.
    pub config: PageRankConfig,
}

impl PageRank {
    /// Runner with configuration.
    pub fn new(config: PageRankConfig) -> Self {
        Self { config }
    }

    /// Run from the variant's uniform initial vector.
    pub fn run(&self, csr: &Csr) -> PageRankResult {
        let n = csr.num_vertices();
        let init = vec![self.config.init_rank(n); n];
        self.run_from(csr, init)
    }

    /// Run from a warm-start vector (must have length == |V|). Warm starts
    /// are how the engine seeds exact recomputations after updates.
    pub fn run_from(&self, csr: &Csr, mut ranks: Vec<f64>) -> PageRankResult {
        let n = csr.num_vertices();
        assert_eq!(ranks.len(), n, "warm start length mismatch");
        if n == 0 {
            return PageRankResult { ranks, iterations: 0, last_delta: 0.0 };
        }
        let cfg = self.config;
        let teleport = cfg.teleport(n);
        let epsilon = cfg.scaled_epsilon(n);
        // Precompute 1/d_out once per snapshot; dangling gets 0.
        let inv_out: Vec<f64> = csr
            .out_degrees()
            .iter()
            .map(|&d| if d == 0 { 0.0 } else { 1.0 / d as f64 })
            .collect();
        let mut contrib = vec![0.0f64; n];
        let mut next = vec![0.0f64; n];
        let mut iterations = 0;
        let mut last_delta = f64::INFINITY;
        for _ in 0..cfg.max_iters {
            // Scale once per source: r_u / d_out(u).
            for u in 0..n {
                contrib[u] = ranks[u] * inv_out[u];
            }
            let dangling_share = if cfg.dangling_redistribution {
                let mass: f64 = csr
                    .out_degrees()
                    .iter()
                    .enumerate()
                    .filter(|(_, &d)| d == 0)
                    .map(|(u, _)| ranks[u])
                    .sum();
                cfg.beta * mass / n as f64
            } else {
                0.0
            };
            // Delta accumulates inside the update loop (fused — saves a
            // full pass over the rank vectors per iteration; §Perf L3-1).
            let mut delta = 0.0;
            for v in 0..n {
                let mut sum = 0.0;
                for &u in csr.row(v as u32) {
                    sum += contrib[u as usize];
                }
                let x = teleport + cfg.beta * sum + dangling_share;
                delta += (x - ranks[v]).abs();
                next[v] = x;
            }
            iterations += 1;
            last_delta = delta;
            std::mem::swap(&mut ranks, &mut next);
            if cfg.epsilon > 0.0 && last_delta < epsilon {
                break;
            }
        }
        PageRankResult { ranks, iterations, last_delta }
    }

    /// Parallel run from the variant's uniform initial vector.
    pub fn run_parallel(&self, csr: &Csr, pool: &ThreadPool) -> PageRankResult {
        let n = csr.num_vertices();
        let init = vec![self.config.init_rank(n); n];
        self.run_parallel_from(csr, init, pool)
    }

    /// Parallel warm-started run: the sharded twin of [`Self::run_from`].
    ///
    /// The destination-vertex range is cut into
    /// [`PageRankConfig::effective_shards`] in-edge-balanced shards once
    /// per call ([`Csr::shards`]); each iteration dispatches one gather
    /// job per shard over `pool`, writing a disjoint slice of the `next`
    /// vector and returning its partial L1 delta. Partials are reduced in
    /// shard order, so for a fixed shard count the result (ranks AND
    /// iteration count) is deterministic — and the ranks themselves are
    /// bit-identical to the serial executor's for *any* shard count,
    /// because each vertex's in-edge sum runs in the serial order.
    pub fn run_parallel_from(
        &self,
        csr: &Csr,
        mut ranks: Vec<f64>,
        pool: &ThreadPool,
    ) -> PageRankResult {
        let n = csr.num_vertices();
        assert_eq!(ranks.len(), n, "warm start length mismatch");
        if n == 0 {
            return PageRankResult { ranks, iterations: 0, last_delta: 0.0 };
        }
        let shards = self.config.effective_shards(pool);
        if shards <= 1 {
            return self.run_from(csr, ranks);
        }
        let cfg = self.config;
        let teleport = cfg.teleport(n);
        let epsilon = cfg.scaled_epsilon(n);
        let inv_out: Vec<f64> = csr
            .out_degrees()
            .iter()
            .map(|&d| if d == 0 { 0.0 } else { 1.0 / d as f64 })
            .collect();
        // Shard bounds + scratch buffers are computed/allocated once per
        // run; per-iteration dispatch reuses them via `scope_chunks`.
        let cuts = csr.shards(shards);
        let mut contrib = vec![0.0f64; n];
        let mut next = vec![0.0f64; n];
        let mut iterations = 0;
        let mut last_delta = f64::INFINITY;
        for _ in 0..cfg.max_iters {
            for u in 0..n {
                contrib[u] = ranks[u] * inv_out[u];
            }
            let dangling_share = if cfg.dangling_redistribution {
                let mass: f64 = csr
                    .out_degrees()
                    .iter()
                    .enumerate()
                    .filter(|(_, &d)| d == 0)
                    .map(|(u, _)| ranks[u])
                    .sum();
                cfg.beta * mass / n as f64
            } else {
                0.0
            };
            // One gather job per shard: shard i owns next[cuts[i]..cuts[i+1]].
            let partials = {
                let ranks = &ranks;
                let contrib = &contrib;
                let cuts_ref = &cuts;
                pool.scope_chunks(&mut next, &cuts, move |i, chunk| {
                    let lo = cuts_ref[i];
                    let mut delta = 0.0f64;
                    for (off, slot) in chunk.iter_mut().enumerate() {
                        let v = lo + off;
                        let mut sum = 0.0;
                        for &u in csr.row(v as u32) {
                            sum += contrib[u as usize];
                        }
                        let x = teleport + cfg.beta * sum + dangling_share;
                        delta += (x - ranks[v]).abs();
                        *slot = x;
                    }
                    delta
                })
            };
            iterations += 1;
            last_delta = partials.iter().sum();
            std::mem::swap(&mut ranks, &mut next);
            if cfg.epsilon > 0.0 && last_delta < epsilon {
                break;
            }
        }
        PageRankResult { ranks, iterations, last_delta }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;

    fn cfg(beta: f64) -> PageRankConfig {
        PageRankConfig {
            beta,
            max_iters: 200,
            epsilon: 1e-12,
            normalized: true,
            ..Default::default()
        }
    }

    #[test]
    fn cycle_is_uniform() {
        // 0->1->2->0: perfectly symmetric, ranks must all equal 1/3.
        let csr = Csr::from_edges(3, &[(0, 1), (1, 2), (2, 0)]);
        let res = PageRank::new(cfg(0.85)).run(&csr);
        for &r in &res.ranks {
            assert!((r - 1.0 / 3.0).abs() < 1e-9, "{:?}", res.ranks);
        }
        assert!(res.last_delta < 1e-12);
        assert!(res.iterations < 200);
    }

    #[test]
    fn star_center_dominates() {
        // spokes 1..=4 all point at 0; 0 points at 1.
        let csr = Csr::from_edges(5, &[(1, 0), (2, 0), (3, 0), (4, 0), (0, 1)]);
        let res = PageRank::new(cfg(0.85)).run(&csr);
        assert!(res.ranks[0] > res.ranks[2]);
        assert!(res.ranks[1] > res.ranks[2], "1 receives from the hub");
        assert!((res.ranks[2] - res.ranks[3]).abs() < 1e-12, "symmetric spokes");
    }

    #[test]
    fn beta_zero_gives_pure_teleport() {
        let csr = Csr::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        let res = PageRank::new(cfg(0.0)).run(&csr);
        for &r in &res.ranks {
            assert!((r - 0.25).abs() < 1e-12);
        }
        assert_eq!(res.iterations, 1, "converges immediately");
    }

    #[test]
    fn ranks_sum_below_one_without_redistribution() {
        // dangling vertex 2 leaks mass
        let csr = Csr::from_edges(3, &[(0, 1), (1, 2)]);
        let res = PageRank::new(cfg(0.85)).run(&csr);
        let total: f64 = res.ranks.iter().sum();
        assert!(total < 1.0, "leaky total {total}");
    }

    #[test]
    fn dangling_redistribution_conserves_mass() {
        let csr = Csr::from_edges(3, &[(0, 1), (1, 2)]);
        let mut c = cfg(0.85);
        c.dangling_redistribution = true;
        let res = PageRank::new(c).run(&csr);
        let total: f64 = res.ranks.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "conserved total {total}");
    }

    #[test]
    fn warm_start_converges_to_same_fixed_point() {
        let csr = Csr::from_edges(5, &[(0, 1), (1, 2), (2, 0), (3, 2), (0, 3), (4, 0), (2, 4)]);
        let pr = PageRank::new(cfg(0.85));
        let cold = pr.run(&csr);
        let warm = pr.run_from(&csr, vec![0.9, 0.02, 0.02, 0.02, 0.04]);
        for (a, b) in cold.ranks.iter().zip(&warm.ranks) {
            assert!((a - b).abs() < 1e-8);
        }
        assert!(warm.last_delta < 1e-12 && cold.last_delta < 1e-12);
    }

    #[test]
    fn epsilon_zero_runs_all_iterations() {
        let csr = Csr::from_edges(3, &[(0, 1), (1, 2), (2, 0)]);
        let mut c = cfg(0.85);
        c.epsilon = 0.0;
        c.max_iters = 17;
        let res = PageRank::new(c).run(&csr);
        assert_eq!(res.iterations, 17);
    }

    #[test]
    fn empty_graph_is_fine() {
        let csr = Csr::from_edges(0, &[]);
        let res = PageRank::default().run(&csr);
        assert!(res.ranks.is_empty());
        assert_eq!(res.iterations, 0);
    }

    #[test]
    fn single_vertex_gets_teleport_only() {
        let csr = Csr::from_edges(1, &[]);
        let res = PageRank::new(cfg(0.85)).run(&csr);
        assert!((res.ranks[0] - 0.15).abs() < 1e-12);
    }

    /// A graph with hubs, dangling vertices and isolated vertices —
    /// exercises every branch of the sharded gather.
    fn gnarly() -> Csr {
        let mut edges = Vec::new();
        for v in 1..40u32 {
            edges.push((v, 0)); // hub in-edges
            if v % 3 != 0 {
                edges.push((0, v)); // hub out-edges
            }
            if v % 5 == 0 && v + 1 < 40 {
                edges.push((v, v + 1));
            }
        }
        // 40..44 are isolated ⇒ out-degree 0 ⇒ dangling.
        Csr::from_edges(45, &edges)
    }

    #[test]
    fn parallel_matches_serial_bit_for_bit() {
        let pool = ThreadPool::new(4);
        let csr = gnarly();
        for normalized in [false, true] {
            for dangling in [false, true] {
                let mut c = cfg(0.85);
                c.normalized = normalized;
                c.dangling_redistribution = dangling;
                c.epsilon = 0.0;
                c.max_iters = 30;
                let serial = PageRank::new(c).run(&csr);
                for shards in [2usize, 3, 4, 7, 64] {
                    c.parallelism = shards;
                    let par = PageRank::new(c).run_parallel(&csr, &pool);
                    assert_eq!(par.iterations, serial.iterations);
                    assert_eq!(
                        par.ranks, serial.ranks,
                        "shards={shards} normalized={normalized} dangling={dangling}"
                    );
                }
            }
        }
    }

    #[test]
    fn parallel_converges_like_serial() {
        let pool = ThreadPool::new(4);
        let csr = gnarly();
        let mut c = cfg(0.85);
        c.parallelism = 4;
        let serial = PageRank::new(cfg(0.85)).run(&csr);
        let par = PageRank::new(c).run_parallel(&csr, &pool);
        assert!(par.last_delta < c.scaled_epsilon(csr.num_vertices()));
        let linf = serial
            .ranks
            .iter()
            .zip(&par.ranks)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(linf < 1e-12, "L∞ {linf}");
    }

    #[test]
    fn parallel_warm_start_matches_serial_warm_start() {
        let pool = ThreadPool::new(3);
        let csr = gnarly();
        let n = csr.num_vertices();
        let warm: Vec<f64> = (0..n).map(|v| 1.0 / (v + 1) as f64).collect();
        let mut c = cfg(0.85);
        c.epsilon = 0.0;
        c.max_iters = 12;
        let serial = PageRank::new(c).run_from(&csr, warm.clone());
        c.parallelism = 5;
        let par = PageRank::new(c).run_parallel_from(&csr, warm, &pool);
        assert_eq!(par.ranks, serial.ranks);
    }

    #[test]
    fn parallel_handles_empty_graph_and_one_shard() {
        let pool = ThreadPool::new(2);
        let empty = Csr::from_edges(0, &[]);
        let mut c = cfg(0.85);
        c.parallelism = 4;
        let res = PageRank::new(c).run_parallel(&empty, &pool);
        assert!(res.ranks.is_empty());
        assert_eq!(res.iterations, 0);
        // parallelism = 1 falls back to the serial path
        c.parallelism = 1;
        let csr = Csr::from_edges(3, &[(0, 1), (1, 2), (2, 0)]);
        let serial = PageRank::new(cfg(0.85)).run(&csr);
        let one = PageRank::new(c).run_parallel(&csr, &pool);
        assert_eq!(one.ranks, serial.ranks);
    }

    #[test]
    fn parallelism_zero_uses_pool_size() {
        let pool = ThreadPool::new(3);
        let mut c = cfg(0.85);
        c.parallelism = 0;
        assert_eq!(c.effective_shards(&pool), 3);
        let csr = Csr::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        let serial = PageRank::new(cfg(0.85)).run(&csr);
        let auto = PageRank::new(c).run_parallel(&csr, &pool);
        assert_eq!(auto.ranks, serial.ranks);
    }
}
