//! Fault injection for the durability subsystem.
//!
//! Durability code is exactly the code that only matters when the
//! process dies at the worst moment — which a passing happy-path test
//! never exercises. This module gives the WAL and checkpoint writers an
//! injectable failure surface so the recovery tests can *manufacture*
//! the worst moments deterministically:
//!
//! * **Named crash points** ([`CrashPoint`]) — the writer consults the
//!   injector at a handful of interesting instants (right after a WAL
//!   record hits the disk, halfway through a checkpoint dump, just
//!   before a snapshot publish) and, if that point is armed, aborts as
//!   if the process had been killed there. What's on disk at that
//!   instant is exactly what a real crash would leave.
//! * **An injectable I/O layer** ([`FaultyIo`] implementing
//!   [`WalIo`]) — simulates short writes, fsync failure and disk-full
//!   by metering a byte budget: once the budget runs out, writes land
//!   partially (a genuine torn tail on disk) and then error, which is
//!   how ENOSPC actually behaves.
//!
//! Production code paths carry `Option<Arc<FaultInjector>>` and pass
//! `None`; the injector costs nothing when absent.

use std::fs::File;
use std::io::{self, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::wal::{SegmentWriter, WalIo};

/// The named instants a crash can be injected at. Arming one makes the
/// next pass through that point behave as if the process died there.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CrashPoint {
    /// Immediately after a WAL record is durably appended, before the
    /// batch is applied to the graph. Recovery must replay the record.
    PostWalAppend,
    /// Halfway through writing a checkpoint file (the partial bytes are
    /// left at the *final* path, as a non-atomic writer dying would).
    /// Recovery must detect the corruption and fall back to the
    /// previous snapshot.
    MidCheckpoint,
    /// Just before a recomputed snapshot is published. The WAL already
    /// holds everything; recovery must reconstruct the unpublished
    /// state from snapshot + tail replay.
    PrePublish,
}

/// Shared fault state consulted by the WAL, the checkpoint writer and
/// the engine's publish path. One injector can drive all of them.
#[derive(Debug)]
pub struct FaultInjector {
    armed: Mutex<Option<CrashPoint>>,
    trips: AtomicU64,
    fail_fsync: AtomicBool,
    /// Remaining writable bytes; `u64::MAX` means unlimited.
    disk_budget: AtomicU64,
    short_writes: AtomicU64,
    fsync_failures: AtomicU64,
}

impl FaultInjector {
    /// A fresh injector with every fault disabled.
    pub fn new() -> Arc<FaultInjector> {
        Arc::new(FaultInjector {
            armed: Mutex::new(None),
            trips: AtomicU64::new(0),
            fail_fsync: AtomicBool::new(false),
            disk_budget: AtomicU64::new(u64::MAX),
            short_writes: AtomicU64::new(0),
            fsync_failures: AtomicU64::new(0),
        })
    }

    /// Arm one crash point. Only one can be armed at a time; arming
    /// replaces any previous one.
    pub fn arm_crash(&self, point: CrashPoint) {
        *self.armed.lock().unwrap() = Some(point);
    }

    /// Consulted by the instrumented code paths: if `point` is armed,
    /// disarm it, count the trip and return true (the caller then
    /// aborts as if killed). One-shot so recovery runs through the same
    /// code without re-crashing.
    pub fn take_crash(&self, point: CrashPoint) -> bool {
        let mut armed = self.armed.lock().unwrap();
        if *armed == Some(point) {
            *armed = None;
            self.trips.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    /// How many crash points have fired.
    pub fn trips(&self) -> u64 {
        self.trips.load(Ordering::Relaxed)
    }

    /// Make every subsequent `sync` fail (until turned off again).
    pub fn set_fail_fsync(&self, on: bool) {
        self.fail_fsync.store(on, Ordering::Relaxed);
    }

    /// Cap the total bytes the faulty I/O layer will write; the write
    /// that crosses the cap lands partially and errors (disk-full).
    pub fn set_disk_budget(&self, bytes: u64) {
        self.disk_budget.store(bytes, Ordering::Relaxed);
    }

    /// Injected short writes observed so far.
    pub fn short_writes(&self) -> u64 {
        self.short_writes.load(Ordering::Relaxed)
    }

    /// Injected fsync failures observed so far.
    pub fn fsync_failures(&self) -> u64 {
        self.fsync_failures.load(Ordering::Relaxed)
    }

    /// Grant up to `want` bytes from the disk budget.
    fn take_disk(&self, want: usize) -> usize {
        let want64 = want as u64;
        let mut granted = want64;
        let _ = self.disk_budget.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |cur| {
            if cur == u64::MAX {
                granted = want64;
                None // unlimited: leave the sentinel in place
            } else {
                granted = cur.min(want64);
                Some(cur - granted)
            }
        });
        granted as usize
    }

    fn fsync_should_fail(&self) -> bool {
        if self.fail_fsync.load(Ordering::Relaxed) {
            self.fsync_failures.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }
}

/// A [`WalIo`] implementation whose segments honor the injector's disk
/// budget and fsync switch. Swap it in via
/// [`DurabilityConfig::io`](crate::coordinator::checkpoint::DurabilityConfig).
pub struct FaultyIo {
    inj: Arc<FaultInjector>,
}

impl FaultyIo {
    /// Wrap an injector as a WAL I/O layer.
    pub fn new(inj: Arc<FaultInjector>) -> FaultyIo {
        FaultyIo { inj }
    }
}

impl WalIo for FaultyIo {
    fn create_segment(&mut self, path: &Path) -> io::Result<Box<dyn SegmentWriter>> {
        let file = File::create(path)?;
        Ok(Box::new(FaultySegment { file, inj: Arc::clone(&self.inj) }))
    }
}

/// One WAL segment under fault control: writes consume the byte budget
/// (crossing it leaves a genuine short write on disk, then errors) and
/// `sync` fails while the fsync switch is on.
struct FaultySegment {
    file: File,
    inj: Arc<FaultInjector>,
}

impl SegmentWriter for FaultySegment {
    fn write_all(&mut self, buf: &[u8]) -> io::Result<()> {
        let granted = self.inj.take_disk(buf.len());
        if granted < buf.len() {
            if granted > 0 {
                self.file.write_all(&buf[..granted])?;
                let _ = self.file.flush();
            }
            self.inj.short_writes.fetch_add(1, Ordering::Relaxed);
            return Err(io::Error::new(
                io::ErrorKind::WriteZero,
                format!("injected disk-full: wrote {granted} of {} bytes", buf.len()),
            ));
        }
        self.file.write_all(buf)
    }

    fn sync(&mut self) -> io::Result<()> {
        if self.inj.fsync_should_fail() {
            return Err(io::Error::other("injected fsync failure"));
        }
        self.file.flush()?;
        self.file.sync_data()
    }
}

/// A [`WalIo`] implementation that models the OS page cache: segment
/// writes land in an in-memory buffer and only reach the real file when
/// `sync` is called. Dropping a segment with unsynced bytes *discards*
/// them — exactly what a power loss does to dirty pages the kernel
/// never flushed. Recovery tests use it to check that
/// [`SyncPolicy::Interval`](crate::coordinator::wal::SyncPolicy)
/// loses at most the records appended since the last sync, and loses
/// them *cleanly* (no torn batch survives).
pub struct VolatileIo;

impl VolatileIo {
    /// A volatile (page-cache-modeling) WAL I/O layer.
    pub fn new() -> VolatileIo {
        VolatileIo
    }
}

impl Default for VolatileIo {
    fn default() -> Self {
        VolatileIo::new()
    }
}

impl WalIo for VolatileIo {
    fn create_segment(&mut self, path: &Path) -> io::Result<Box<dyn SegmentWriter>> {
        // Create (truncate) the real file eagerly so the segment exists
        // on disk with whatever prefix gets synced — an empty file if
        // nothing ever does, as after a real crash.
        let file = File::create(path)?;
        Ok(Box::new(VolatileSegment { file, buf: Vec::new() }))
    }
}

/// One WAL segment behind a simulated page cache: `write_all` only
/// dirties the in-memory buffer; `sync` flushes it to the file and
/// fsyncs; dropping without sync throws the dirty tail away.
struct VolatileSegment {
    file: File,
    buf: Vec<u8>,
}

impl SegmentWriter for VolatileSegment {
    fn write_all(&mut self, buf: &[u8]) -> io::Result<()> {
        self.buf.extend_from_slice(buf);
        Ok(())
    }

    fn sync(&mut self) -> io::Result<()> {
        self.file.write_all(&self.buf)?;
        self.file.sync_data()?;
        self.buf.clear();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crash_points_are_one_shot() {
        let inj = FaultInjector::new();
        assert!(!inj.take_crash(CrashPoint::PostWalAppend), "unarmed never fires");
        inj.arm_crash(CrashPoint::MidCheckpoint);
        assert!(!inj.take_crash(CrashPoint::PostWalAppend), "wrong point stays armed");
        assert!(inj.take_crash(CrashPoint::MidCheckpoint));
        assert!(!inj.take_crash(CrashPoint::MidCheckpoint), "fires exactly once");
        assert_eq!(inj.trips(), 1);
    }

    #[test]
    fn disk_budget_meters_and_short_writes() {
        let inj = FaultInjector::new();
        assert_eq!(inj.take_disk(100), 100, "unlimited by default");
        inj.set_disk_budget(10);
        assert_eq!(inj.take_disk(4), 4);
        assert_eq!(inj.take_disk(100), 6, "partial grant at the cliff");
        assert_eq!(inj.take_disk(1), 0, "then nothing");
    }

    #[test]
    fn faulty_segment_leaves_partial_bytes_then_errors() {
        let dir = std::env::temp_dir()
            .join(format!("vg-faults-{}-{:?}", std::process::id(), std::thread::current().id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("seg.log");
        let inj = FaultInjector::new();
        inj.set_disk_budget(6);
        let mut io_layer = FaultyIo::new(Arc::clone(&inj));
        let mut seg = io_layer.create_segment(&path).unwrap();
        seg.write_all(b"full").unwrap();
        let err = seg.write_all(b"overflow").unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::WriteZero);
        assert_eq!(inj.short_writes(), 1);
        drop(seg);
        let on_disk = std::fs::read(&path).unwrap();
        assert_eq!(on_disk, b"fullov", "short write left a genuine torn tail");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fsync_switch_fails_sync_only() {
        let dir = std::env::temp_dir()
            .join(format!("vg-fsync-{}-{:?}", std::process::id(), std::thread::current().id()));
        std::fs::create_dir_all(&dir).unwrap();
        let inj = FaultInjector::new();
        let mut io_layer = FaultyIo::new(Arc::clone(&inj));
        let mut seg = io_layer.create_segment(&dir.join("seg.log")).unwrap();
        seg.write_all(b"data").unwrap();
        inj.set_fail_fsync(true);
        assert!(seg.sync().is_err());
        assert_eq!(inj.fsync_failures(), 1);
        inj.set_fail_fsync(false);
        assert!(seg.sync().is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn volatile_segment_loses_unsynced_tail() {
        let dir = std::env::temp_dir()
            .join(format!("vg-vol-{}-{:?}", std::process::id(), std::thread::current().id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("seg.log");
        let mut io_layer = VolatileIo::new();
        let mut seg = io_layer.create_segment(&path).unwrap();
        seg.write_all(b"synced-").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"", "dirty pages never hit the file");
        seg.sync().unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"synced-");
        seg.write_all(b"lost").unwrap();
        drop(seg); // crash: the dirty tail evaporates
        assert_eq!(std::fs::read(&path).unwrap(), b"synced-", "unsynced tail discarded");
        std::fs::remove_dir_all(&dir).ok();
    }
}
