//! Test-support substrates, including the `vprop` mini property-testing
//! framework (proptest substitute; see DESIGN.md §Substitutions).

pub mod vprop;
