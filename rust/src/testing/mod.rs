//! Test-support substrates: the `vprop` mini property-testing framework
//! (proptest substitute; see DESIGN.md §Substitutions) and the shared
//! sequential-apply oracle batch paths are verified against.

pub mod faults;
pub mod oracle;
pub mod vprop;
