//! `vprop` — a miniature property-based testing framework (substrate for
//! the unavailable `proptest` crate).
//!
//! Deterministic: each case is generated from a seeded PRNG; on failure
//! the reporting includes the case index and seed so the exact input can
//! be replayed. A simple halving shrinker is provided for sized inputs.
//!
//! ```no_run
//! use veilgraph::testing::vprop::{forall, Gen};
//! forall(100, 42, |g: &mut Gen| {
//!     let xs = g.vec_u64(0..50, 0..1000);
//!     let mut sorted = xs.clone();
//!     sorted.sort_unstable();
//!     assert!(sorted.len() == xs.len());
//! });
//! ```

use crate::util::rng::Xoshiro256pp;

/// Case-local generator handed to property closures.
pub struct Gen {
    rng: Xoshiro256pp,
    /// Seed of this particular case (printed on failure).
    pub case_seed: u64,
}

impl Gen {
    /// Create a generator for one case.
    pub fn new(case_seed: u64) -> Self {
        Self { rng: Xoshiro256pp::new(case_seed), case_seed }
    }

    /// u64 in [lo, hi).
    pub fn u64(&mut self, range: std::ops::Range<u64>) -> u64 {
        assert!(range.start < range.end);
        range.start + self.rng.next_below(range.end - range.start)
    }

    /// usize in [lo, hi).
    pub fn usize(&mut self, range: std::ops::Range<usize>) -> usize {
        self.u64(range.start as u64..range.end as u64) as usize
    }

    /// f64 in [lo, hi).
    pub fn f64(&mut self, range: std::ops::Range<f64>) -> f64 {
        range.start + self.rng.next_f64() * (range.end - range.start)
    }

    /// Bernoulli draw.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Vector of u64s with random length in `len` and values in `vals`.
    pub fn vec_u64(
        &mut self,
        len: std::ops::Range<usize>,
        vals: std::ops::Range<u64>,
    ) -> Vec<u64> {
        let n = self.usize(len.start..len.end.max(len.start + 1));
        (0..n).map(|_| self.u64(vals.clone())).collect()
    }

    /// Random edge list over `n` vertices (may contain duplicates — pair
    /// with `DynamicGraph::from_edges` which counts them).
    pub fn edges(&mut self, n: usize, m: usize) -> Vec<(u64, u64)> {
        (0..m)
            .map(|_| (self.u64(0..n as u64), self.u64(0..n as u64)))
            .filter(|(u, v)| u != v)
            .collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0..xs.len())]
    }

    /// Access the underlying PRNG.
    pub fn rng(&mut self) -> &mut Xoshiro256pp {
        &mut self.rng
    }
}

/// Run `cases` property checks; panics (re-raising the property's panic)
/// with the case index + seed on first failure.
pub fn forall<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(cases: u32, seed: u64, prop: F) {
    let mut meta = crate::util::rng::SplitMix64::new(seed);
    for case in 0..cases {
        let case_seed = meta.next_u64();
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(case_seed);
            prop(&mut g);
        });
        if let Err(p) = result {
            eprintln!(
                "vprop: property failed at case {case}/{cases}, case_seed={case_seed:#x} \
                 (outer seed {seed})"
            );
            std::panic::resume_unwind(p);
        }
    }
}

/// Replay a single failing case by its printed `case_seed`.
pub fn replay<F: FnOnce(&mut Gen)>(case_seed: u64, prop: F) {
    let mut g = Gen::new(case_seed);
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        use std::sync::atomic::{AtomicU32, Ordering};
        static COUNT: AtomicU32 = AtomicU32::new(0);
        forall(50, 1, |_g| {
            COUNT.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(COUNT.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn generators_respect_ranges() {
        forall(200, 2, |g| {
            let x = g.u64(10..20);
            assert!((10..20).contains(&x));
            let f = g.f64(-1.0..1.0);
            assert!((-1.0..1.0).contains(&f));
            let v = g.vec_u64(0..5, 0..3);
            assert!(v.len() < 5);
            assert!(v.iter().all(|&x| x < 3));
        });
    }

    #[test]
    fn failure_is_reported_with_seed() {
        let r = std::panic::catch_unwind(|| {
            forall(100, 3, |g| {
                let x = g.u64(0..100);
                assert!(x != 7, "hit the bad value");
            });
        });
        assert!(r.is_err(), "property with a bad value in range must fail");
    }

    #[test]
    fn replay_reproduces_case() {
        let mut captured = 0u64;
        replay(0xDEADBEEF, |g| {
            captured = g.u64(0..1000);
        });
        let mut again = 0u64;
        replay(0xDEADBEEF, |g| {
            again = g.u64(0..1000);
        });
        assert_eq!(captured, again);
    }

    #[test]
    fn edges_have_no_self_loops() {
        forall(50, 4, |g| {
            let es = g.edges(20, 50);
            assert!(es.iter().all(|(u, v)| u != v));
        });
    }
}
