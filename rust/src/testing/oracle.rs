//! Sequential reference application of raw op streams.
//!
//! The one oracle every batch path is measured against: ops applied one
//! at a time through the public per-op API, in order, skipping the ones
//! the graph rejects (missing endpoints, unknown edges). `apply_batch`,
//! the update buffer's coalescer and the engine's batched ingest must
//! all leave a graph bit-identical to this reference — the property and
//! unit suites previously each carried their own copy of it, which is
//! exactly how oracle drift starts.

use crate::graph::dynamic::DynamicGraph;
use crate::stream::event::EdgeOp;

/// Apply `ops` sequentially through the per-op API. Returns
/// `(applied, skipped)`; callers that only want the end state ignore it.
pub fn seq_apply(g: &mut DynamicGraph, ops: &[EdgeOp]) -> (usize, usize) {
    let (mut applied, mut skipped) = (0, 0);
    for op in ops {
        let ok = match *op {
            EdgeOp::AddEdge(u, v) => g.add_edge(u, v).is_ok(),
            EdgeOp::RemoveEdge(u, v) => g.remove_edge(u, v).is_ok(),
            EdgeOp::AddVertex(u) => {
                g.add_vertex(u);
                true
            }
            EdgeOp::RemoveVertex(u) => g.remove_vertex(u).is_ok(),
        };
        if ok {
            applied += 1;
        } else {
            skipped += 1;
        }
    }
    (applied, skipped)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_counts_applied_and_skipped() {
        let (mut g, _) = DynamicGraph::from_edges(vec![(1, 2)]);
        let ops = vec![
            EdgeOp::add(1, 3),         // applied; endpoint 3 auto-vivified
            EdgeOp::remove(9, 9),      // skipped: unknown edge
            EdgeOp::AddVertex(7),      // applied
            EdgeOp::RemoveVertex(100), // skipped: unknown vertex
        ];
        let (applied, skipped) = seq_apply(&mut g, &ops);
        assert_eq!((applied, skipped), (2, 2));
        assert!(g.index(7).is_some() && g.index(3).is_some());
    }
}
