//! Hot-vertex selection: `K = K_r ∪ K_n ∪ K_Δ` (§3.2, Eqs. 2–5).
//!
//! 1. **`K_r`** — update-ratio threshold (Eq. 2): vertices whose total
//!    degree changed by more than ratio `r` between measurement points
//!    `t-1` and `t`. New vertices (no previous degree) are always
//!    included (paper footnote 2).
//! 2. **`K_n`** — uniform neighborhood expansion of diameter `n` around
//!    `K_r` (Eq. 3).
//! 3. **`K_Δ`** — score-sensitive extension (Eqs. 4–5): from each vertex
//!    `v` in the frontier so far, expand an extra radius
//!    `f_Δ(v) = log(n + d̄·v_s / (Δ·d_t(v))) / log d̄`.
//!
//!    The paper's prose motivates `f_Δ` by contribution decay: `v`'s
//!    rank contribution dilutes by a factor ~`d̄` per hop, so hops are
//!    followed until the contribution falls below a `Δ` fraction of
//!    `v_s`. Eq. 4's quantifier structure (radius indexed by the
//!    *candidate*) is not directly computable by forward search, so we
//!    implement the decay interpretation: each already-hot vertex `v`
//!    expands with per-seed budget `⌊f_Δ(v)⌋`, which matches both the
//!    worked example (“with Δ = 0.1, we keep considering further hops
//!    from v until the contribution drops below 10% of its score”) and
//!    the reference implementation's breadth-first expansion. Budgets are
//!    clamped to [`MAX_DELTA_RADIUS`] to bound worst-case work.
//!
//! [`compute_hot_set_pooled`] is the engine's entry point: every stage
//! shards over the engine's pool and borrows its O(|V|) working state
//! from a reusable [`SummaryScratch`]; [`compute_hot_set`] is the
//! serial, self-contained wrapper with identical output.

use std::collections::HashMap;

use crate::graph::dynamic::DynamicGraph;
use crate::graph::traversal::{bfs_budgeted_pooled, bfs_multi_pooled, Direction};
use crate::graph::{VertexId, VertexIdx};
use crate::summary::params::SummaryParams;
use crate::summary::scratch::SummaryScratch;
use crate::util::threadpool::ThreadPool;

/// Safety clamp on the per-vertex Δ-expansion radius.
pub const MAX_DELTA_RADIUS: u32 = 8;

/// Below this many touched vertices the `K_r` scan runs inline — the
/// per-entry predicate is two loads and a compare.
const MIN_PARALLEL_KR: usize = 1024;

/// The selected hot set with per-tier membership (for figures/ablation).
///
/// Invariant: every tier is ascending by dense index and the tiers are
/// mutually disjoint — the shape [`compute_hot_set`] produces.
/// Hand-built instances should sort their tiers so [`HotSet::all`]
/// stays a linear merge (unsorted tiers still merge correctly via its
/// fallback sort, at the old O(|K| log |K|) cost).
#[derive(Clone, Debug, Default)]
pub struct HotSet {
    /// Vertices from the update-ratio threshold (Eq. 2).
    pub k_r: Vec<VertexIdx>,
    /// Added by uniform expansion (Eq. 3), disjoint from `k_r`.
    pub k_n: Vec<VertexIdx>,
    /// Added by Δ-extension (Eq. 4), disjoint from the others.
    pub k_delta: Vec<VertexIdx>,
    /// Membership bitmap over dense indices (`true` ⇔ hot).
    pub hot: Vec<bool>,
}

impl HotSet {
    /// All hot vertices (`K`), sorted ascending. The tiers are each
    /// sorted and mutually disjoint (the shape [`compute_hot_set`]
    /// produces), so the union is a linear three-way merge — no
    /// re-collect-and-sort on the once-per-build call path.
    pub fn all(&self) -> Vec<VertexIdx> {
        let mut out = Vec::with_capacity(self.len());
        let (mut a, mut b, mut c) = (0usize, 0usize, 0usize);
        loop {
            let x = self.k_r.get(a);
            let y = self.k_n.get(b);
            let z = self.k_delta.get(c);
            let m = match [x, y, z].into_iter().flatten().min() {
                Some(&m) => m,
                None => break,
            };
            if x == Some(&m) {
                a += 1;
            } else if y == Some(&m) {
                b += 1;
            } else {
                c += 1;
            }
            out.push(m);
        }
        // Each merge step consumes exactly one tier element and pushes
        // it, so `out` is always a permutation of the tiers' union even
        // if a hand-built HotSet violated the sortedness invariant —
        // one O(|K|) check (plus a fallback sort only on violation)
        // keeps the old sort-always contract in release builds.
        if !out.windows(2).all(|w| w[0] <= w[1]) {
            out.sort_unstable();
        }
        out
    }

    /// |K|.
    pub fn len(&self) -> usize {
        self.k_r.len() + self.k_n.len() + self.k_delta.len()
    }

    /// True if no vertex is hot.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Membership test by dense index.
    #[inline]
    pub fn contains(&self, v: VertexIdx) -> bool {
        self.hot.get(v as usize).copied().unwrap_or(false)
    }
}

/// Inputs capturing the state between two measurement points.
pub struct HotSetInputs<'a> {
    /// The graph *after* applying this measurement point's updates.
    pub graph: &'a DynamicGraph,
    /// `d_{t-1}` for vertices touched by the applied updates (absent ⇒
    /// untouched, degree unchanged ⇒ cannot enter `K_r`).
    pub prev_degree: &'a HashMap<VertexId, usize>,
    /// Vertices that did not exist before this measurement point.
    pub new_vertices: &'a [VertexId],
    /// Previous ranks per dense index (may be shorter than |V| if the
    /// graph grew; missing entries default to 0 — “no established score”).
    pub prev_ranks: &'a [f64],
}

/// Eq. 5: the Δ-expansion radius for vertex `v`.
///
/// `mean_deg` is `d̄`, the average degree of currently accumulated
/// vertices; `score` is `v_s`. Guards: degenerate `d̄ <= 1` (log ≤ 0)
/// yields radius 0; `d_t(v) = 0` is treated as 1 (an isolated vertex has
/// nothing to dilute through).
pub fn delta_radius(params: &SummaryParams, mean_deg: f64, score: f64, degree: usize) -> u32 {
    if mean_deg <= 1.0 || score <= 0.0 {
        return 0;
    }
    let d = degree.max(1) as f64;
    let inner = params.n as f64 + mean_deg * score / (params.delta * d);
    if inner <= 1.0 {
        return 0;
    }
    let f = inner.ln() / mean_deg.ln();
    let f = f.max(0.0).min(MAX_DELTA_RADIUS as f64);
    f.floor() as u32
}

/// Compute `K = K_r ∪ K_n ∪ K_Δ` for one measurement point.
///
/// Convenience wrapper over [`compute_hot_set_pooled`] with a throwaway
/// scratch and no pool — the output is identical to the pooled variant
/// at every shard count.
pub fn compute_hot_set(inputs: &HotSetInputs<'_>, params: &SummaryParams) -> HotSet {
    let mut scratch = SummaryScratch::new();
    compute_hot_set_pooled(inputs, params, &mut scratch, None, 1)
}

/// Eq. 2 candidates from the degree baseline. The per-entry predicate is
/// pure, so large touched sets shard across the pool; the returned set
/// is schedule-independent (order is not — callers sort).
fn kr_candidates(
    g: &DynamicGraph,
    prev_degree: &HashMap<VertexId, usize>,
    params: &SummaryParams,
    pool: Option<&ThreadPool>,
    shards: usize,
) -> Vec<VertexIdx> {
    let crossed = |idx: VertexIdx, d_prev: usize| -> bool {
        let d_now = g.degree(idx);
        if d_prev == 0 {
            // Degree was zero: any growth is an infinite ratio.
            d_now > 0
        } else {
            let ratio = d_now as f64 / d_prev as f64;
            (ratio - 1.0).abs() > params.r
        }
    };
    match pool {
        Some(pool) if shards > 1 && prev_degree.len() >= MIN_PARALLEL_KR => {
            let entries: Vec<(VertexIdx, usize)> = prev_degree
                .iter()
                .filter_map(|(&id, &d)| g.index(id).map(|idx| (idx, d)))
                .collect();
            if entries.is_empty() {
                // Every touched id has left the graph — nothing to scan.
                return Vec::new();
            }
            let k = shards.min(entries.len());
            let ecuts: Vec<usize> = (0..=k).map(|i| i * entries.len() / k).collect();
            let slots = pool.scope_slots(k, |i| {
                let mut out = Vec::new();
                for &(idx, d_prev) in &entries[ecuts[i]..ecuts[i + 1]] {
                    if crossed(idx, d_prev) {
                        out.push(idx);
                    }
                }
                out
            });
            slots.concat()
        }
        _ => {
            let mut out = Vec::new();
            for (&id, &d_prev) in prev_degree {
                if let Some(idx) = g.index(id) {
                    if crossed(idx, d_prev) {
                        out.push(idx);
                    }
                }
            }
            out
        }
    }
}

/// Pooled twin of [`compute_hot_set`]: the `K_r` scan, the `K_n` uniform
/// expansion and the `K_Δ` budgeted expansion all shard across `pool`
/// (`shards` many cuts; serial when the pool is absent or `shards <= 1`),
/// and all O(|V|) working state — the hot bitmap and the BFS visit
/// arrays — comes from `scratch` instead of fresh allocations. The
/// result is bit-identical to the serial wrapper for every shard count:
/// tier membership is schedule-independent (level-synchronous claims,
/// monotone budget relaxation, a pure `K_r` predicate) and every tier is
/// sorted. Recycle the result's bitmap with
/// [`SummaryScratch::recycle_hot`] once the query is served.
pub fn compute_hot_set_pooled(
    inputs: &HotSetInputs<'_>,
    params: &SummaryParams,
    scratch: &mut SummaryScratch,
    pool: Option<&ThreadPool>,
    shards: usize,
) -> HotSet {
    let g = inputs.graph;
    let nv = g.num_vertices();
    let shards = shards.max(1);
    scratch.prepare_traversal(nv);
    let mut hot = scratch.take_hot(nv);

    // ---- Eq. 2: K_r --------------------------------------------------
    let mut k_r: Vec<VertexIdx> = Vec::new();
    for idx in kr_candidates(g, inputs.prev_degree, params, pool, shards) {
        if !hot[idx as usize] {
            hot[idx as usize] = true;
            k_r.push(idx);
        }
    }
    for &id in inputs.new_vertices {
        if let Some(idx) = g.index(id) {
            if !hot[idx as usize] {
                hot[idx as usize] = true;
                k_r.push(idx);
            }
        }
    }
    k_r.sort_unstable();

    // ---- Eq. 3: K_n --------------------------------------------------
    let mut k_n: Vec<VertexIdx> = Vec::new();
    if params.n > 0 && !k_r.is_empty() {
        let reached =
            bfs_multi_pooled(g, &k_r, params.n, Direction::Both, scratch.bfs_mut(), pool, shards);
        for (v, depth) in reached {
            if depth > 0 && !hot[v as usize] {
                hot[v as usize] = true;
                k_n.push(v);
            }
        }
        k_n.sort_unstable();
    }

    // ---- Eqs. 4–5: K_Δ -----------------------------------------------
    // Seeds: every currently hot vertex expands by its own decay radius.
    let mean_deg = g.mean_degree();
    let mut seeds: Vec<(VertexIdx, u32)> = Vec::with_capacity(k_r.len() + k_n.len());
    for &v in k_r.iter().chain(&k_n) {
        let score = inputs.prev_ranks.get(v as usize).copied().unwrap_or(0.0);
        let radius = delta_radius(params, mean_deg, score, g.degree(v));
        if radius > 0 {
            seeds.push((v, radius));
        }
    }
    let mut k_delta: Vec<VertexIdx> = Vec::new();
    if !seeds.is_empty() {
        let reached =
            bfs_budgeted_pooled(g, &seeds, Direction::Both, scratch.bfs_mut(), pool, shards);
        for v in reached {
            if !hot[v as usize] {
                hot[v as usize] = true;
                k_delta.push(v);
            }
        }
        // Already ascending (the budgeted walk reports sorted indices);
        // kept as a sort for belt-and-suspenders parity with the tiers.
        k_delta.sort_unstable();
    }

    HotSet { k_r, k_n, k_delta, hot }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Path 0→1→2→3→4→5 with user ids equal to indices.
    fn path6() -> DynamicGraph {
        DynamicGraph::from_edges((0..5u64).map(|i| (i, i + 1))).0
    }

    fn inputs<'a>(
        g: &'a DynamicGraph,
        prev: &'a HashMap<VertexId, usize>,
        newv: &'a [VertexId],
        ranks: &'a [f64],
    ) -> HotSetInputs<'a> {
        HotSetInputs { graph: g, prev_degree: prev, new_vertices: newv, prev_ranks: ranks }
    }

    #[test]
    fn kr_includes_only_vertices_past_threshold() {
        let g = path6();
        // vertex 0 degree unchanged (1→1); vertex 2 doubled (1→2).
        let prev: HashMap<u64, usize> = [(0, 1), (2, 1)].into_iter().collect();
        let ranks = vec![0.0; 6];
        let hs = compute_hot_set(&inputs(&g, &prev, &[], &ranks), &SummaryParams::new(0.5, 0, 9.0));
        assert_eq!(hs.k_r, vec![g.index(2).unwrap()]);
        assert!(hs.k_n.is_empty());
    }

    #[test]
    fn ratio_threshold_is_strict_inequality() {
        let g = path6();
        // vertex 2: prev 1, now 2 ⇒ ratio change = 1.0 exactly.
        let prev: HashMap<u64, usize> = [(2, 1)].into_iter().collect();
        let ranks = vec![0.0; 6];
        let hs = compute_hot_set(&inputs(&g, &prev, &[], &ranks), &SummaryParams::new(1.0, 0, 9.0));
        assert!(hs.is_empty(), "|ratio-1| == r must NOT be included (Eq. 2 is >)");
    }

    #[test]
    fn degree_decrease_also_triggers() {
        let g = path6();
        // vertex 3: prev degree 4, now 2 ⇒ |2/4 - 1| = 0.5 > 0.3.
        let prev: HashMap<u64, usize> = [(3, 4)].into_iter().collect();
        let ranks = vec![0.0; 6];
        let hs = compute_hot_set(&inputs(&g, &prev, &[], &ranks), &SummaryParams::new(0.3, 0, 9.0));
        assert_eq!(hs.k_r.len(), 1);
    }

    #[test]
    fn new_vertices_always_enter_kr() {
        let g = path6();
        let prev = HashMap::new();
        let ranks = vec![0.0; 6];
        let hs =
            compute_hot_set(&inputs(&g, &prev, &[5], &ranks), &SummaryParams::new(0.9, 0, 9.0));
        assert_eq!(hs.k_r, vec![g.index(5).unwrap()]);
    }

    #[test]
    fn kn_expands_n_hops_both_directions() {
        let g = path6();
        let prev: HashMap<u64, usize> = [(2, 1)].into_iter().collect(); // 2 doubled
        let ranks = vec![0.0; 6];
        let hs = compute_hot_set(&inputs(&g, &prev, &[], &ranks), &SummaryParams::new(0.5, 1, 9.0));
        // K_r = {2}; n=1 reaches 1 and 3.
        let i = |u: u64| g.index(u).unwrap();
        assert_eq!(hs.k_r, vec![i(2)]);
        assert_eq!(hs.k_n, vec![i(1), i(3)]);
        assert!(!hs.contains(i(0)) && !hs.contains(i(4)));
    }

    #[test]
    fn delta_radius_monotonic_in_score_and_delta() {
        let p_small = SummaryParams::new(0.1, 1, 0.01);
        let p_big = SummaryParams::new(0.1, 1, 0.9);
        let d = 10.0;
        // higher score ⇒ larger radius
        assert!(delta_radius(&p_small, d, 0.5, 4) >= delta_radius(&p_small, d, 0.001, 4));
        // smaller Δ ⇒ larger radius (more conservative)
        assert!(delta_radius(&p_small, d, 0.01, 4) >= delta_radius(&p_big, d, 0.01, 4));
        // clamped
        assert!(delta_radius(&p_small, d, 1e12, 1) <= MAX_DELTA_RADIUS);
    }

    #[test]
    fn delta_radius_guards_degenerate_inputs() {
        let p = SummaryParams::new(0.1, 1, 0.1);
        assert_eq!(delta_radius(&p, 0.5, 1.0, 1), 0, "mean degree <= 1");
        assert_eq!(delta_radius(&p, 10.0, 0.0, 1), 0, "zero score");
        assert_eq!(delta_radius(&p, 10.0, -1.0, 1), 0, "negative score");
    }

    #[test]
    fn kdelta_extends_past_kn_with_high_scores() {
        let g = path6();
        // vertex 1: degree 2 now, was 4 ⇒ |2/4 - 1| = 0.5 > 0.3 ⇒ K_r.
        let prev: HashMap<u64, usize> = [(1, 4)].into_iter().collect();
        let mut ranks = vec![0.0; 6];
        ranks[g.index(1).unwrap() as usize] = 0.9; // huge score ⇒ big radius
        let p = SummaryParams::new(0.3, 0, 0.001);
        let hs = compute_hot_set(&inputs(&g, &prev, &[], &ranks), &p);
        assert_eq!(hs.k_r.len(), 1);
        assert!(hs.k_n.is_empty());
        // With mean degree ~1.67 > 1 and big score, Δ-expansion reaches out.
        assert!(!hs.k_delta.is_empty(), "expected Δ expansion, got {hs:?}");
        // tiers are disjoint
        let all = hs.all();
        let set: std::collections::HashSet<_> = all.iter().collect();
        assert_eq!(set.len(), all.len());
    }

    #[test]
    fn all_merges_sorted_tiers_and_tolerates_unsorted_ones() {
        let hs = HotSet { k_r: vec![0, 4], k_n: vec![2], k_delta: vec![1, 5], hot: vec![] };
        assert_eq!(hs.all(), vec![0, 1, 2, 4, 5]);
        // Hand-built tiers that violate the sortedness invariant fall
        // back to the old sort-always behavior instead of mis-merging.
        let unsorted = HotSet { k_r: vec![5, 2], k_n: vec![], k_delta: vec![4, 0], hot: vec![] };
        assert_eq!(unsorted.all(), vec![0, 2, 4, 5]);
    }

    #[test]
    fn untouched_graph_yields_empty_hot_set() {
        let g = path6();
        let prev = HashMap::new();
        let ranks = vec![0.1; 6];
        let hs =
            compute_hot_set(&inputs(&g, &prev, &[], &ranks), &SummaryParams::new(0.1, 1, 0.01));
        assert!(hs.is_empty());
        assert!(hs.all().is_empty());
    }

    #[test]
    fn prev_ranks_shorter_than_graph_is_ok() {
        let g = path6();
        let prev: HashMap<u64, usize> = [(5, 1)].into_iter().collect();
        let ranks = vec![0.5; 2]; // graph has 6 vertices
        let hs =
            compute_hot_set(&inputs(&g, &prev, &[], &ranks), &SummaryParams::new(0.1, 1, 0.01));
        // must not panic; vertex 5 degree 1→1 unchanged ⇒ empty or small
        let _ = hs.len();
    }
}
