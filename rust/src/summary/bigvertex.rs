//! Summary-graph construction with the big vertex `B` (§3.1).
//!
//! For the original graph `G = (V, E)` and hot set `K`, the summary graph
//! is `G = (K ∪ {B}, E_K ∪ E_B)` where:
//!
//! * `E_K = {(u,v) ∈ E : u,v ∈ K}` — internal edges, each carrying
//!   `val((u,v)) = 1/d_out(u)` with `d_out` taken in the FULL graph
//!   before discarding (edges leaving `K` still count toward the degree
//!   that divides `u`'s emitted score — §3.1).
//! * `E_B = {(w,z) ∈ E : w ∉ K, z ∈ K}` — boundary edges, each carrying
//!   the frozen contribution `val((w,z)) = w_s/d_out(w)` of its non-hot
//!   source. We accumulate them per target as `b_z`; Eq. 1's scalar
//!   `B_s = Σ val` is kept for reporting.
//!
//! Edges *into* `B` are discarded entirely (the rank of `B` is
//! irrelevant), which is what makes the summarized computation `O(|K|)`.

use crate::graph::csr::balanced_cuts;
use crate::graph::dynamic::DynamicGraph;
use crate::graph::VertexIdx;
use crate::summary::hot::HotSet;
use crate::summary::scratch::SummaryScratch;
use crate::util::threadpool::ThreadPool;

/// Per-row aggregates from the counting pass of the parallel build.
#[derive(Clone, Copy, Default)]
struct RowAgg {
    /// Internal (E_K) in-edges of this row.
    internal: u32,
    /// Boundary contribution `b_z`, accumulated in in-neighbor order.
    b: f64,
    /// Warm-start rank.
    r0: f64,
}

/// `1 / d_out(w)` as f64 (0 for dangling) — the uncached twin of
/// [`SummaryScratch::inv_out`]; both yield the same bits, so serial
/// (memoized) and sharded (inline) builds agree exactly.
#[inline]
fn inv_out_of(g: &DynamicGraph, w: VertexIdx) -> f64 {
    let d = g.out_degree(w);
    if d == 0 {
        0.0
    } else {
        1.0 / d as f64
    }
}

/// The summarized problem, ready for either executor (sparse rust-native
/// or dense-padded XLA).
#[derive(Clone, Debug, PartialEq)]
pub struct SummaryGraph {
    /// Hot vertices in dense-graph index space, sorted; position = local
    /// index.
    pub vertices: Vec<VertexIdx>,
    /// CSR over internal edges, pull orientation: `in_offsets[z]..` lists
    /// `(local_src, weight)` pairs with `weight = 1/d_out(src)`.
    pub in_offsets: Vec<u32>,
    pub in_edges: Vec<(u32, f32)>,
    /// Frozen big-vertex contribution per local target (`b_z`).
    pub b: Vec<f64>,
    /// Previous rank per local vertex (warm start `r_0`).
    pub r0: Vec<f64>,
    /// |E_B| (boundary edges folded into `b`).
    pub num_boundary_edges: usize,
    /// Eq. 1's `B_s = Σ_{(w,z) ∈ E_B} val((w,z))` (reporting only).
    pub b_s: f64,
    /// |V| of the FULL graph at this measurement point (teleport uses it
    /// so summary ranks stay comparable with full-graph ranks).
    pub full_n: usize,
}

impl SummaryGraph {
    /// Build the summary graph for hot set `hot` over `g`.
    ///
    /// `prev_ranks[i]` is the previous measurement point's rank for dense
    /// index `i`; vertices beyond its length (new vertices) warm-start at
    /// `default_rank` (the PageRank variant's init value — see
    /// [`crate::pagerank::power::PageRankConfig::init_rank`]).
    ///
    /// Convenience wrapper over [`Self::build_pooled`] with a throwaway
    /// scratch and no pool; the engine calls the pooled variant with its
    /// long-lived workspace.
    pub fn build(
        g: &DynamicGraph,
        hot: &HotSet,
        prev_ranks: &[f64],
        default_rank: f64,
    ) -> SummaryGraph {
        let mut scratch = SummaryScratch::new();
        Self::build_pooled(g, hot, prev_ranks, default_rank, &mut scratch, None, 1)
    }

    /// Build the summary graph, reusing `scratch` for all O(|V|) working
    /// state and sharding the construction over `pool` when `shards > 1`.
    ///
    /// The parallel form is a two-pass degree-balanced build over
    /// [`balanced_cuts`] row ranges of the hot-vertex list: pass 1 walks
    /// each range's in-neighbors once, producing per-row internal-edge
    /// counts, boundary sums `b_z` and warm starts; a serial O(|K|)
    /// prefix sum turns the counts into `in_offsets`; pass 2 fills
    /// disjoint `in_edges` slices in the same in-neighbor order the
    /// serial path uses. Per-source inverse out-degrees are computed
    /// once (epoch-memoized serially, inline in the shards — same bits)
    /// instead of one division per edge, and `b_s` reduces over `b` in
    /// local-index order. Output is bit-identical to [`Self::build`] for
    /// every shard count.
    pub fn build_pooled(
        g: &DynamicGraph,
        hot: &HotSet,
        prev_ranks: &[f64],
        default_rank: f64,
        scratch: &mut SummaryScratch,
        pool: Option<&ThreadPool>,
        shards: usize,
    ) -> SummaryGraph {
        let vertices = hot.all();
        let k = vertices.len();
        let full_n = g.num_vertices();
        scratch.prepare_build(full_n);
        for (li, &v) in vertices.iter().enumerate() {
            scratch.set_local(v, li as u32);
        }
        let rank_of = |v: VertexIdx| prev_ranks.get(v as usize).copied().unwrap_or(default_rank);
        let shards = shards.clamp(1, k.max(1));
        match pool {
            Some(pool) if shards > 1 => {
                Self::fill_pooled(g, vertices, &rank_of, scratch, pool, shards, full_n)
            }
            _ => Self::fill_serial(g, vertices, &rank_of, scratch, full_n),
        }
    }

    /// Single-pass serial fill (the original build shape, now reading
    /// the scratch's epoch-stamped maps instead of a fresh |V| table).
    fn fill_serial(
        g: &DynamicGraph,
        vertices: Vec<VertexIdx>,
        rank_of: &impl Fn(VertexIdx) -> f64,
        scratch: &mut SummaryScratch,
        full_n: usize,
    ) -> SummaryGraph {
        let k = vertices.len();
        let mut in_offsets = Vec::with_capacity(k + 1);
        in_offsets.push(0u32);
        let mut in_edges: Vec<(u32, f32)> = Vec::new();
        let mut b = vec![0.0f64; k];
        let mut r0 = Vec::with_capacity(k);
        let mut num_boundary_edges = 0usize;
        for (li, &z) in vertices.iter().enumerate() {
            r0.push(rank_of(z));
            for &w in g.in_neighbors(z) {
                debug_assert!(g.out_degree(w) > 0, "in-neighbor must have an out-edge");
                match scratch.local_get(w) {
                    Some(wl) => {
                        // internal edge (E_K): weight 1/d_out in the FULL graph
                        in_edges.push((wl, scratch.inv_out(g, w) as f32));
                    }
                    None => {
                        // boundary edge (E_B): frozen contribution of w
                        b[li] += rank_of(w) * scratch.inv_out(g, w);
                        num_boundary_edges += 1;
                    }
                }
            }
            in_offsets.push(in_edges.len() as u32);
        }
        let b_s: f64 = b.iter().sum();
        SummaryGraph { vertices, in_offsets, in_edges, b, r0, num_boundary_edges, b_s, full_n }
    }

    /// Two-pass sharded fill (see [`Self::build_pooled`]).
    fn fill_pooled(
        g: &DynamicGraph,
        vertices: Vec<VertexIdx>,
        rank_of: &(impl Fn(VertexIdx) -> f64 + Sync),
        scratch: &mut SummaryScratch,
        pool: &ThreadPool,
        shards: usize,
        full_n: usize,
    ) -> SummaryGraph {
        let k = vertices.len();
        let cuts = balanced_cuts(k, shards, |li| g.in_degree(vertices[li]) as u64);
        let local = scratch.local_view();
        let vertices_ref = &vertices;

        // Pass 1: per-row aggregates over disjoint row ranges.
        let mut rows: Vec<RowAgg> = vec![RowAgg::default(); k];
        let cuts_ref = &cuts;
        let boundary_counts = pool.scope_chunks(&mut rows, &cuts, |i, chunk| {
            let lo = cuts_ref[i];
            let mut boundary = 0usize;
            for (off, row) in chunk.iter_mut().enumerate() {
                let z = vertices_ref[lo + off];
                row.r0 = rank_of(z);
                for &w in g.in_neighbors(z) {
                    debug_assert!(g.out_degree(w) > 0, "in-neighbor must have an out-edge");
                    if local.get(w).is_some() {
                        row.internal += 1;
                    } else {
                        row.b += rank_of(w) * inv_out_of(g, w);
                        boundary += 1;
                    }
                }
            }
            boundary
        });
        let num_boundary_edges: usize = boundary_counts.iter().sum();

        // Serial O(|K|) prefix sum of the internal-edge counts.
        let mut in_offsets = Vec::with_capacity(k + 1);
        in_offsets.push(0u32);
        for row in &rows {
            in_offsets.push(in_offsets.last().unwrap() + row.internal);
        }
        let total = *in_offsets.last().unwrap() as usize;

        // Pass 2: each range owns a disjoint in_edges slice; rows fill in
        // in-neighbor order — the serial order.
        let mut in_edges: Vec<(u32, f32)> = vec![(0, 0.0); total];
        let ecuts: Vec<usize> = cuts.iter().map(|&r| in_offsets[r] as usize).collect();
        pool.scope_chunks(&mut in_edges, &ecuts, |i, chunk| {
            let mut cursor = 0usize;
            for &z in &vertices_ref[cuts_ref[i]..cuts_ref[i + 1]] {
                for &w in g.in_neighbors(z) {
                    if let Some(wl) = local.get(w) {
                        chunk[cursor] = (wl, inv_out_of(g, w) as f32);
                        cursor += 1;
                    }
                }
            }
            debug_assert_eq!(cursor, chunk.len(), "fill must cover its slice exactly");
        });

        let b: Vec<f64> = rows.iter().map(|r| r.b).collect();
        let r0: Vec<f64> = rows.iter().map(|r| r.r0).collect();
        let b_s: f64 = b.iter().sum();
        SummaryGraph { vertices, in_offsets, in_edges, b, r0, num_boundary_edges, b_s, full_n }
    }

    /// |K| — number of hot vertices.
    pub fn num_vertices(&self) -> usize {
        self.vertices.len()
    }

    /// |E_K| — internal edges.
    pub fn num_internal_edges(&self) -> usize {
        self.in_edges.len()
    }

    /// |E| = |E_K| + |E_B| — the paper's summary edge count.
    pub fn num_edges(&self) -> usize {
        self.in_edges.len() + self.num_boundary_edges
    }

    /// Internal in-edges of local vertex `z`.
    #[inline]
    pub fn row(&self, z: usize) -> &[(u32, f32)] {
        &self.in_edges[self.in_offsets[z] as usize..self.in_offsets[z + 1] as usize]
    }

    /// Split the local-vertex range into `k` contiguous shards balanced
    /// by internal in-edge count — the summary-graph twin of
    /// [`crate::graph::csr::Csr::shards`], consumed by
    /// `pagerank::summarized::run_summarized_parallel`. Same contract:
    /// `k + 1` ascending cut points, deterministic for a fixed `(summary,
    /// k)`.
    pub fn shards(&self, k: usize) -> Vec<usize> {
        crate::graph::csr::balanced_cuts(self.num_vertices(), k, |z| {
            (self.in_offsets[z + 1] - self.in_offsets[z]) as u64
        })
    }

    /// Densify into padded row-major `A[z*cap + u] = val((u,z))`, plus the
    /// padded `r0`, `b` and `mask` vectors the XLA artifacts consume.
    /// Panics if `capacity < |K|` (the runtime picks the tier first).
    pub fn to_dense(&self, capacity: usize) -> DenseSummary {
        let k = self.num_vertices();
        assert!(capacity >= k, "capacity {capacity} < |K| = {k}");
        let mut a = vec![0.0f32; capacity * capacity];
        for z in 0..k {
            let row = &mut a[z * capacity..(z + 1) * capacity];
            for &(u, w) in self.row(z) {
                // Parallel internal edges cannot exist (DynamicGraph
                // rejects duplicates) — plain assignment.
                row[u as usize] = w;
            }
        }
        let mut r0 = vec![0.0f32; capacity];
        let mut b = vec![0.0f32; capacity];
        let mut mask = vec![0.0f32; capacity];
        for z in 0..k {
            r0[z] = self.r0[z] as f32;
            b[z] = self.b[z] as f32;
            mask[z] = 1.0;
        }
        DenseSummary { a, r0, b, mask, capacity, k }
    }
}

/// Padded dense form consumed by the AOT PageRank artifacts.
#[derive(Clone, Debug)]
pub struct DenseSummary {
    /// Row-major `capacity × capacity` transition matrix.
    pub a: Vec<f32>,
    /// Padded warm-start ranks.
    pub r0: Vec<f32>,
    /// Padded big-vertex contributions.
    pub b: Vec<f32>,
    /// 1.0 on the first `k` rows.
    pub mask: Vec<f32>,
    /// Padded dimension.
    pub capacity: usize,
    /// Valid rows.
    pub k: usize,
}

/// The big-vertex aggregate applied to a *remote shard* instead of the
/// cold set: per-iteration rank mass crossing a shard boundary, rolled
/// up per local destination the way [`SummaryGraph`]'s `b` rolls up
/// frozen boundary contributions per hot target.
///
/// In the summarized path, `b[z] = Σ r(w)/d_out(w)` over boundary edges
/// `(w, z)` with `w` frozen in the big vertex B. In the sharded exchange
/// (`pagerank::sharded`), the "big vertex" is another shard: each source
/// shard accumulates `r(u)/d_out(u)` over its cut edges `(u, v)` into
/// the destination shard's inbox at `v`'s local index, the destination
/// folds the inbox into its gather and the inbox clears for the next
/// iteration. Unlike `SummaryGraph::b`, these contributions are
/// re-exchanged every iteration — which is why the sharded run converges
/// to the exact fixed point instead of an approximation.
#[derive(Clone, Debug, Default)]
pub struct RemoteAggregate {
    /// Aggregated incoming mass per local destination index.
    b: Vec<f64>,
    /// Cut-edge contributions folded in since the last clear.
    boundary_edges: usize,
}

impl RemoteAggregate {
    /// An empty inbox for a shard with `n` local vertex slots.
    pub fn new(n: usize) -> Self {
        Self { b: vec![0.0; n], boundary_edges: 0 }
    }

    /// Accumulate one cut edge's mass at local destination `target`.
    #[inline]
    pub fn add(&mut self, target: VertexIdx, mass: f64) {
        self.b[target as usize] += mass;
        self.boundary_edges += 1;
    }

    /// Aggregated mass per local destination.
    pub fn b(&self) -> &[f64] {
        &self.b
    }

    /// Total aggregated mass (the exchange twin of [`SummaryGraph::b_s`]).
    pub fn b_s(&self) -> f64 {
        self.b.iter().sum()
    }

    /// Cut-edge contributions folded in since the last clear.
    pub fn num_boundary_edges(&self) -> usize {
        self.boundary_edges
    }

    /// Fold the inbox into a gather accumulator (`acc[v] += b[v]`).
    pub fn fold_into(&self, acc: &mut [f64]) {
        for (a, &m) in acc.iter_mut().zip(&self.b) {
            *a += m;
        }
    }

    /// Zero the inbox for the next exchange round.
    pub fn clear(&mut self) {
        self.b.iter_mut().for_each(|m| *m = 0.0);
        self.boundary_edges = 0;
    }

    /// Zero the inbox and resize it to `n` local slots, keeping the
    /// allocation when the shard has not grown — the reuse path for
    /// exchange scratch carried across recomputes.
    pub fn reset(&mut self, n: usize) {
        self.b.clear();
        self.b.resize(n, 0.0);
        self.boundary_edges = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::summary::hot::HotSet;

    /// Build a HotSet directly from a list of dense indices.
    fn hot_of(g: &DynamicGraph, idxs: &[VertexIdx]) -> HotSet {
        let mut hot = vec![false; g.num_vertices()];
        for &i in idxs {
            hot[i as usize] = true;
        }
        HotSet { k_r: idxs.to_vec(), k_n: vec![], k_delta: vec![], hot }
    }

    /// 0→1, 0→2, 1→2, 3→1, 3→0, 2→3  (ids == dense indices).
    fn g6() -> DynamicGraph {
        DynamicGraph::from_edges(vec![(0, 1), (0, 2), (1, 2), (3, 1), (3, 0), (2, 3)]).0
    }

    #[test]
    fn internal_edges_carry_inverse_full_outdegree() {
        let g = g6();
        // K = {0, 1, 2}: edges inside: 0→1, 0→2, 1→2.
        let hot = hot_of(&g, &[0, 1, 2]);
        let prev = vec![0.1, 0.2, 0.3, 0.4];
        let s = SummaryGraph::build(&g, &hot, &prev, 0.0);
        assert_eq!(s.num_vertices(), 3);
        assert_eq!(s.num_internal_edges(), 3);
        // d_out(0) = 2 (both edges stay in K) ⇒ weight 0.5
        let row1 = s.row(1); // in-edges of local 1 (dense 1): from 0 and from 3(boundary)
        assert_eq!(row1.len(), 1);
        assert_eq!(row1[0], (0, 0.5));
        // row 2: from 0 (0.5) and from 1 (d_out(1) = 1 ⇒ 1.0)
        let mut row2 = s.row(2).to_vec();
        row2.sort_by_key(|&(u, _)| u);
        assert_eq!(row2, vec![(0, 0.5), (1, 1.0)]);
    }

    #[test]
    fn outgoing_edges_leaving_k_still_count_in_degree() {
        let g = g6();
        // K = {2, 3}: edge 2→3 internal; d_out(2) = 1 ⇒ weight 1.0.
        // BUT consider K = {0, 1}: edge 0→1 internal, d_out(0)=2 even
        // though 0→2 leaves K — the weight must still be 1/2.
        let hot = hot_of(&g, &[0, 1]);
        let s = SummaryGraph::build(&g, &hot, &[0.1, 0.2, 0.3, 0.4], 0.0);
        let row1 = s.row(1);
        assert_eq!(row1.len(), 1);
        assert_eq!(row1[0].1, 0.5, "degree counts edges leaving K");
    }

    #[test]
    fn boundary_contributions_freeze_prev_ranks() {
        let g = g6();
        let prev = vec![0.1, 0.2, 0.3, 0.4];
        // K = {0, 1}: boundary in-edges: 3→1, 3→0 (w = 3, d_out(3) = 2).
        let hot = hot_of(&g, &[0, 1]);
        let s = SummaryGraph::build(&g, &hot, &prev, 0.0);
        assert_eq!(s.num_boundary_edges, 2);
        let expect = prev[3] / 2.0;
        assert!((s.b[0] - expect).abs() < 1e-12); // into 0
        assert!((s.b[1] - expect).abs() < 1e-12); // into 1
        assert!((s.b_s - 2.0 * expect).abs() < 1e-12, "Eq. 1 aggregate");
        assert_eq!(s.num_edges(), 1 + 2); // E_K = {0→1}, E_B = 2
    }

    #[test]
    fn edges_into_big_vertex_are_discarded() {
        let g = g6();
        // K = {3}: in-edge 2→3 is boundary; out-edges 3→0, 3→1 vanish.
        let hot = hot_of(&g, &[3]);
        let s = SummaryGraph::build(&g, &hot, &[0.1, 0.2, 0.3, 0.4], 0.0);
        assert_eq!(s.num_internal_edges(), 0);
        assert_eq!(s.num_boundary_edges, 1);
        assert!((s.b[0] - 0.3 / 1.0).abs() < 1e-12); // d_out(2) = 1
    }

    #[test]
    fn warm_start_and_new_vertex_defaults() {
        let g = g6();
        let hot = hot_of(&g, &[1, 3]);
        // prev_ranks shorter than |V| — vertex 3 has no previous rank.
        let prev = vec![0.1, 0.2, 0.3];
        let default = 0.15 / 4.0;
        let s = SummaryGraph::build(&g, &hot, &prev, default);
        assert!((s.r0[0] - 0.2).abs() < 1e-12);
        assert!((s.r0[1] - default).abs() < 1e-12);
    }

    #[test]
    fn empty_hot_set_builds_empty_summary() {
        let g = g6();
        let hot = hot_of(&g, &[]);
        let s = SummaryGraph::build(&g, &hot, &[0.1, 0.2, 0.3, 0.4], 0.0);
        assert_eq!(s.num_vertices(), 0);
        assert_eq!(s.num_edges(), 0);
        assert_eq!(s.b_s, 0.0);
    }

    #[test]
    fn to_dense_lays_out_row_major_with_mask() {
        let g = g6();
        let hot = hot_of(&g, &[0, 1, 2]);
        let prev = vec![0.1, 0.2, 0.3, 0.4];
        let s = SummaryGraph::build(&g, &hot, &prev, 0.0);
        let d = s.to_dense(4);
        assert_eq!(d.capacity, 4);
        assert_eq!(d.k, 3);
        // A[z=1, u=0] = 0.5
        assert_eq!(d.a[1 * 4 + 0], 0.5);
        // A[z=2, u=1] = 1.0
        assert_eq!(d.a[2 * 4 + 1], 1.0);
        // padding row 3 all zeros
        assert!(d.a[12..16].iter().all(|&x| x == 0.0));
        assert_eq!(d.mask, vec![1.0, 1.0, 1.0, 0.0]);
        assert_eq!(d.r0[3], 0.0);
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn to_dense_rejects_small_capacity() {
        let g = g6();
        let hot = hot_of(&g, &[0, 1, 2]);
        let s = SummaryGraph::build(&g, &hot, &[0.0; 4], 0.0);
        s.to_dense(2);
    }
}
