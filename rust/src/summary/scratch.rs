//! Reusable workspace for the summarized-query pipeline.
//!
//! Every stage of the summarized path used to allocate (and clear)
//! O(|V|)-sized scratch per query: the hot-membership bitmap, the BFS
//! depth/budget arrays, and the dense→local index map. The engine now
//! owns ONE [`SummaryScratch`] and reuses it across queries, three
//! mechanisms keeping the steady state free of O(|V|) work:
//!
//! * **Epoch stamping** — the `local_of` and `inv_out` maps pair every
//!   entry with the epoch that wrote it; bumping the epoch invalidates
//!   all entries in O(1) instead of an O(|V|) clear.
//! * **Dirty-list resets** — the BFS arrays ([`BfsScratch`]) are
//!   restored by walking the (small) reached set, not the whole array.
//! * **Bitmap recycling** — the hot bitmap returns to the scratch after
//!   each query and is scrubbed via the tier lists (O(|K|)).
//!
//! [`SummaryScratch::stats`] counts growth vs pure-reuse acquisitions so
//! tests and the engine's metrics can assert that a steady-state
//! summarized query allocates nothing proportional to |V|.

use crate::graph::dynamic::DynamicGraph;
use crate::graph::traversal::BfsScratch;
use crate::graph::VertexIdx;
use crate::summary::hot::HotSet;

/// Growth/reuse counters over scratch acquisitions
/// ([`SummaryScratch::prepare_traversal`]/[`SummaryScratch::prepare_build`]/
/// [`SummaryScratch::take_hot`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScratchStats {
    /// Acquisitions that had to grow a buffer (first query, or the graph
    /// gained vertices since the last one).
    pub grown: u64,
    /// Acquisitions served entirely from recycled buffers.
    pub reused: u64,
}

/// The engine-owned workspace shared by hot-set selection
/// ([`crate::summary::hot::compute_hot_set_pooled`]) and summary
/// construction ([`crate::summary::bigvertex::SummaryGraph::build_pooled`]).
#[derive(Debug, Default)]
pub struct SummaryScratch {
    /// Current epoch; stamped entries from older epochs are stale.
    epoch: u64,
    local_epoch: Vec<u64>,
    local_of: Vec<u32>,
    inv_epoch: Vec<u64>,
    inv_out: Vec<f64>,
    bfs: BfsScratch,
    hot: Option<Vec<bool>>,
    stats: ScratchStats,
}

/// Read-only dense→local view for sharded build closures (no `&mut`
/// aliasing of the scratch inside `scope_chunks` jobs).
pub struct LocalView<'a> {
    epoch: u64,
    stamps: &'a [u64],
    local: &'a [u32],
}

impl LocalView<'_> {
    /// Local summary index of dense vertex `v`, if `v` is hot this epoch.
    #[inline]
    pub fn get(&self, v: VertexIdx) -> Option<u32> {
        let i = v as usize;
        (self.stamps[i] == self.epoch).then_some(self.local[i])
    }
}

impl SummaryScratch {
    /// Empty scratch; every buffer grows on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// Size the BFS visit arrays for a traversal stage over `n` vertices
    /// (the stamped maps are untouched — a hot-set pass never reads
    /// them, so a throwaway scratch stays as lean as the pre-scratch
    /// code). Records a growth or pure-reuse event in [`Self::stats`].
    pub fn prepare_traversal(&mut self, n: usize) {
        let grew = self.bfs.ensure(n);
        self.note(grew);
    }

    /// Start a summary-build stage over `n` vertices: bumps the epoch
    /// (O(1) invalidation of the stamped `local_of`/`inv_out` maps) and
    /// grows them if smaller than `n` (the BFS arrays are untouched).
    /// Records a growth or pure-reuse event in [`Self::stats`].
    pub fn prepare_build(&mut self, n: usize) {
        self.epoch += 1;
        let mut grew = false;
        if self.local_of.len() < n {
            self.local_epoch.resize(n, 0);
            self.local_of.resize(n, 0);
            self.inv_epoch.resize(n, 0);
            self.inv_out.resize(n, 0.0);
            grew = true;
        }
        self.note(grew);
    }

    fn note(&mut self, grew: bool) {
        if grew {
            self.stats.grown += 1;
        } else {
            self.stats.reused += 1;
        }
    }

    /// Growth/reuse counters (monotonic over the scratch's lifetime).
    pub fn stats(&self) -> ScratchStats {
        self.stats
    }

    /// The BFS visit-state component (for the traversal twins).
    pub fn bfs_mut(&mut self) -> &mut BfsScratch {
        &mut self.bfs
    }

    /// Borrow the recycled hot bitmap sized to exactly `n`, all-false.
    /// Return it with [`Self::recycle_hot`] once the query is served; if
    /// it is never returned the next call simply allocates afresh (and
    /// counts as a growth event).
    pub fn take_hot(&mut self, n: usize) -> Vec<bool> {
        let taken = self.hot.take();
        let grew = match &taken {
            Some(h) => h.capacity() < n,
            None => true,
        };
        self.note(grew);
        let mut hot = taken.unwrap_or_default();
        debug_assert!(hot.iter().all(|&b| !b), "recycled bitmap must come back clean");
        hot.resize(n, false);
        hot
    }

    /// Return the hot bitmap, scrubbing exactly the bits the tiers set
    /// (O(|K|), not O(|V|)). Consumes the hot set — the engine is done
    /// with it once the summary is built.
    pub fn recycle_hot(&mut self, hs: HotSet) {
        let HotSet { k_r, k_n, k_delta, mut hot } = hs;
        for &v in k_r.iter().chain(&k_n).chain(&k_delta) {
            if let Some(slot) = hot.get_mut(v as usize) {
                *slot = false;
            }
        }
        debug_assert!(hot.iter().all(|&b| !b), "tier lists must cover every set bit");
        self.hot = Some(hot);
    }

    /// Stamp dense vertex `v` as local summary index `li` for this epoch.
    #[inline]
    pub fn set_local(&mut self, v: VertexIdx, li: u32) {
        self.local_epoch[v as usize] = self.epoch;
        self.local_of[v as usize] = li;
    }

    /// Local index of `v` if stamped this epoch.
    #[inline]
    pub fn local_get(&self, v: VertexIdx) -> Option<u32> {
        let i = v as usize;
        (self.local_epoch[i] == self.epoch).then_some(self.local_of[i])
    }

    /// Shareable view over the local map for parallel fills.
    pub fn local_view(&self) -> LocalView<'_> {
        LocalView { epoch: self.epoch, stamps: &self.local_epoch, local: &self.local_of }
    }

    /// Memoized `1 / d_out(w)` (0 for dangling `w`), computed at most
    /// once per vertex per epoch — the summary build divides once per
    /// *source*, not once per edge. The f64 value rounded to f32 equals
    /// direct f32 division (f64→f32 double rounding is exact for
    /// division), so memoized and inline weights are bit-identical.
    #[inline]
    pub fn inv_out(&mut self, g: &DynamicGraph, w: VertexIdx) -> f64 {
        let i = w as usize;
        if self.inv_epoch[i] != self.epoch {
            self.inv_epoch[i] = self.epoch;
            let d = g.out_degree(w);
            self.inv_out[i] = if d == 0 { 0.0 } else { 1.0 / d as f64 };
        }
        self.inv_out[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hs(k_r: Vec<VertexIdx>, hot: Vec<bool>) -> HotSet {
        HotSet { k_r, k_n: vec![], k_delta: vec![], hot }
    }

    #[test]
    fn epoch_bump_invalidates_local_map() {
        let mut s = SummaryScratch::new();
        s.prepare_build(8);
        s.set_local(3, 0);
        assert_eq!(s.local_get(3), Some(0));
        assert_eq!(s.local_get(4), None);
        s.prepare_build(8);
        assert_eq!(s.local_get(3), None, "old epoch must be invisible");
        let view = s.local_view();
        assert_eq!(view.get(3), None);
    }

    #[test]
    fn inv_out_memoizes_per_epoch() {
        let (g, _) = DynamicGraph::from_edges(vec![(0u64, 1), (0, 2), (2, 1)]);
        let mut s = SummaryScratch::new();
        s.prepare_build(g.num_vertices());
        let i0 = g.index(0).unwrap();
        assert_eq!(s.inv_out(&g, i0), 0.5);
        assert_eq!(s.inv_out(&g, i0), 0.5);
        let i1 = g.index(1).unwrap();
        assert_eq!(s.inv_out(&g, i1), 0.0, "dangling source");
    }

    #[test]
    fn hot_bitmap_recycles_clean() {
        let mut s = SummaryScratch::new();
        let mut hot = s.take_hot(6);
        assert_eq!(hot.len(), 6);
        hot[1] = true;
        hot[4] = true;
        s.recycle_hot(hs(vec![1, 4], hot));
        let again = s.take_hot(6);
        assert!(again.iter().all(|&b| !b));
        // Sizes down and back up to whatever the caller asks for.
        s.recycle_hot(hs(vec![], again));
        assert_eq!(s.take_hot(3).len(), 3);
    }

    #[test]
    fn stats_count_growth_then_reuse() {
        let mut s = SummaryScratch::new();
        // First query: every acquisition grows (BFS arrays, bitmap, maps).
        s.prepare_traversal(10);
        let hot = s.take_hot(10);
        s.prepare_build(10);
        s.recycle_hot(hs(vec![], hot));
        assert_eq!(s.stats(), ScratchStats { grown: 3, reused: 0 });
        // Steady state: a same-size query never grows again.
        s.prepare_traversal(10);
        let hot = s.take_hot(10);
        s.prepare_build(10);
        s.recycle_hot(hs(vec![], hot));
        assert_eq!(s.stats(), ScratchStats { grown: 3, reused: 3 });
        // The graph grew: every buffer must re-size once.
        s.prepare_traversal(12);
        let hot = s.take_hot(12);
        s.prepare_build(12);
        s.recycle_hot(hs(vec![], hot));
        assert_eq!(s.stats(), ScratchStats { grown: 6, reused: 3 });
    }
}
