//! The paper's core contribution: hot-vertex selection `(r, n, Δ)` and
//! big-vertex summary-graph construction, plus the engine-owned
//! [`scratch::SummaryScratch`] workspace that keeps the whole summarized
//! pipeline free of per-query O(|V|) allocations.

pub mod bigvertex;
pub mod hot;
pub mod params;
pub mod scratch;
