//! The paper's core contribution: hot-vertex selection `(r, n, Δ)` and
//! big-vertex summary-graph construction.

pub mod bigvertex;
pub mod hot;
pub mod params;
