//! The VeilGraph model parameters `(r, n, Δ)` (§3.2).

/// Parameters controlling hot-vertex selection.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SummaryParams {
    /// Update-ratio threshold `r` (Eq. 2): minimum relative degree change
    /// for a vertex to enter `K_r`.
    pub r: f64,
    /// Neighborhood diameter `n` (Eq. 3): uniform BFS expansion around
    /// `K_r`.
    pub n: u32,
    /// Vertex-specific extension `Δ` (Eqs. 4–5): score-sensitive extra
    /// expansion; smaller Δ expands further (more conservative).
    pub delta: f64,
}

impl SummaryParams {
    /// Construct parameters; `r >= 0`, `delta > 0`.
    pub fn new(r: f64, n: u32, delta: f64) -> Self {
        assert!(r >= 0.0, "r must be non-negative");
        assert!(delta > 0.0, "delta must be positive");
        Self { r, n, delta }
    }

    /// The paper's 18-combination evaluation grid (§5.2):
    /// r ∈ {0.10, 0.20, 0.30} × n ∈ {0, 1} × Δ ∈ {0.01, 0.1, 0.9}.
    pub fn paper_grid() -> Vec<SummaryParams> {
        let mut out = Vec::with_capacity(18);
        for &r in &[0.10, 0.20, 0.30] {
            for &n in &[0u32, 1] {
                for &delta in &[0.01, 0.1, 0.9] {
                    out.push(SummaryParams::new(r, n, delta));
                }
            }
        }
        out
    }

    /// Label used in figures/CSVs, e.g. `r0.10-n1-d0.010`.
    pub fn label(&self) -> String {
        format!("r{:.2}-n{}-d{:.3}", self.r, self.n, self.delta)
    }
}

impl std::fmt::Display for SummaryParams {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "(r={:.2}, n={}, Δ={:.3})", self.r, self.n, self.delta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_has_18_unique_combinations() {
        let g = SummaryParams::paper_grid();
        assert_eq!(g.len(), 18);
        let labels: std::collections::HashSet<_> = g.iter().map(|p| p.label()).collect();
        assert_eq!(labels.len(), 18);
    }

    #[test]
    fn label_is_stable() {
        assert_eq!(SummaryParams::new(0.1, 1, 0.01).label(), "r0.10-n1-d0.010");
    }

    #[test]
    #[should_panic(expected = "delta")]
    fn zero_delta_rejected() {
        SummaryParams::new(0.1, 0, 0.0);
    }
}
