//! `veilgraph` — the leader binary.
//!
//! Subcommands:
//! * `serve`      — run the query server (TCP JSON line protocol).
//! * `generate`   — emit a synthetic dataset stand-in as TSV.
//! * `experiment` — run the paper's protocol for one dataset, write CSVs.
//! * `figures`    — regenerate paper figures (Table 1 + Figs. 3–30).
//! * `info`       — artifact/platform diagnostics.

use veilgraph::coordinator::checkpoint::DurabilityConfig;
use veilgraph::coordinator::engine::EngineBuilder;
use veilgraph::coordinator::policies::StalenessPolicy;
use veilgraph::coordinator::server::{serve_tcp_with, ServeOptions, ServerHandle};
use veilgraph::coordinator::sharded::ShardedEngineBuilder;
use veilgraph::coordinator::wal::SyncPolicy;
use veilgraph::error::{Error, Result};
use veilgraph::experiments::datasets::{all_datasets, dataset_by_name, table1};
use veilgraph::experiments::figures::{figure_by_number, figures_for_dataset, render_figure};
use veilgraph::experiments::harness::{run_experiment, HarnessConfig};
use veilgraph::experiments::report::{headline, write_experiment};
use veilgraph::graph::io::{load_edges, save_edges};
use veilgraph::pagerank::power::PageRankConfig;
use veilgraph::stream::backpressure::OverflowPolicy;
use veilgraph::summary::params::SummaryParams;
use veilgraph::util::cli::Command;
use veilgraph::util::timer::fmt_duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&args) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let (cmd, rest) = match args.split_first() {
        Some((c, rest)) => (c.as_str(), rest),
        None => ("help", &[] as &[String]),
    };
    match cmd {
        "serve" => cmd_serve(rest),
        "generate" => cmd_generate(rest),
        "experiment" => cmd_experiment(rest),
        "figures" => cmd_figures(rest),
        "info" => cmd_info(rest),
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(Error::Usage(format!("unknown command {other:?}\n\n{}", usage()))),
    }
}

fn usage() -> String {
    "veilgraph — streaming graph approximations\n\n\
     commands:\n\
       serve       run the query server (TCP JSON line protocol)\n\
       generate    emit a synthetic dataset stand-in as TSV\n\
       experiment  run the paper's protocol for one dataset\n\
       figures     regenerate paper figures (Table 1 + Figs. 3-30)\n\
       info        artifact/platform diagnostics\n\n\
     run `veilgraph <command> --help` for options"
        .to_string()
}

fn params_from(p: &veilgraph::util::cli::Parsed) -> Result<SummaryParams> {
    Ok(SummaryParams::new(
        p.req_parse::<f64>("r")?,
        p.req_parse::<u32>("n")?,
        p.req_parse::<f64>("delta")?,
    ))
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let cmd = Command::new("serve", "run the VeilGraph query server")
        .opt("addr", "listen address", Some("127.0.0.1:7421"))
        .opt("graph", "initial graph TSV (default: empty graph)", None)
        .opt("dataset", "or: generate a stand-in dataset by name", None)
        .opt("scale", "stand-in scale factor", Some("0.05"))
        .opt("r", "update-ratio threshold", Some("0.2"))
        .opt("n", "neighborhood diameter", Some("1"))
        .opt("delta", "vertex-specific extension Δ", Some("0.1"))
        .opt("artifacts", "artifacts dir for the XLA backend", Some("artifacts"))
        .opt("queue", "ingestion queue capacity", Some("65536"))
        .opt(
            "overflow",
            "full-queue policy for blocking producers: block, drop-oldest, reject",
            Some("block"),
        )
        .opt(
            "policy",
            "staleness spec `repeatlast:AGE:UPD[,approx:AGE:UPD]` \
             (age in seconds, UPD in effective updates; default: engine default)",
            None,
        )
        .opt("parallelism", "PageRank shards (1 = serial, 0 = one per core)", Some("1"))
        .opt(
            "shards",
            "partition the graph across N engines with cross-shard PageRank \
             exchange (1 = single engine; >1 disables --data-dir/--communities)",
            Some("1"),
        )
        .opt("workers", "poll workers ticking the connections", Some("4"))
        .opt("max-conns", "simultaneous TCP client connections", Some("4096"))
        .opt("rate-limit", "per-connection read ops/sec (0 = unlimited)", Some("0"))
        .opt("top-k", "top entries pre-ranked per published snapshot", Some("128"))
        .opt(
            "window",
            "sliding window in seconds: edges expire via generated RemoveEdge \
             batches (0 = unbounded)",
            Some("0"),
        )
        .opt(
            "data-dir",
            "durability directory: WAL + crash-consistent checkpoints; \
             restart recovers snapshot + log tail (default: in-memory only)",
            None,
        )
        .opt(
            "durability",
            "WAL sync policy: none, batch, or interval:MS",
            Some("batch"),
        )
        .opt("checkpoint-every", "applied batches between checkpoints", Some("64"))
        .opt(
            "recompute-workers",
            "dedicated recompute-pool workers (0/1 = run jobs single-threaded)",
            Some("0"),
        )
        .flag(
            "no-reconcile",
            "discard fence-missed recomputes instead of replaying post-fence ops",
        )
        .flag("communities", "run streaming label propagation as a second standing workload")
        .flag("no-xla", "force the sparse executor")
        .flag("help", "show usage");
    let p = cmd.parse(args)?;
    if p.flag("help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let edges = initial_edges(&p)?;
    let mut opts = ServeOptions::new()
        .queue_capacity(p.req_parse::<usize>("queue")?)
        .overflow(p.req_parse::<OverflowPolicy>("overflow")?)
        .workers(p.req_parse::<usize>("workers")?)
        .max_connections(p.req_parse::<usize>("max-conns")?)
        .rate_limit(p.req_parse::<f64>("rate-limit")?)
        .window_secs(p.req_parse::<f64>("window")?)
        .communities(p.flag("communities"))
        .recompute_workers(p.req_parse::<usize>("recompute-workers")?)
        .reconcile(!p.flag("no-reconcile"));
    if let Some(policy) = p.get_parse::<StalenessPolicy>("policy")? {
        opts = opts.policy(policy);
    }
    let shards = p.req_parse::<usize>("shards")?;
    if shards > 1 {
        if p.get("data-dir").is_some() {
            return Err(Error::Usage(
                "--data-dir is single-engine only; drop it or use --shards 1".into(),
            ));
        }
        if p.flag("communities") {
            return Err(Error::Usage(
                "--communities is single-engine only; drop it or use --shards 1".into(),
            ));
        }
        let pr = PageRankConfig {
            parallelism: p.req_parse::<usize>("parallelism")?,
            ..PageRankConfig::default()
        };
        let engine = ShardedEngineBuilder::new(shards)
            .pagerank(pr)
            .published_top_k(p.req_parse::<usize>("top-k")?)
            .build_from_edges(edges)?;
        println!(
            "sharded engine ready: {} shards, |V|={}, cut edges={}",
            engine.shard_count(),
            engine.latest_snapshot().num_vertices(),
            engine.cut_edges()
        );
        let handle = ServerHandle::spawn_sharded(engine, &opts);
        return serve_tcp_with(handle, p.get("addr").unwrap(), opts);
    }
    let mut builder = EngineBuilder::new()
        .params(params_from(&p)?)
        .parallelism(p.req_parse::<usize>("parallelism")?)
        .published_top_k(p.req_parse::<usize>("top-k")?);
    if !p.flag("no-xla") {
        let dir = p.get("artifacts").unwrap();
        if std::path::Path::new(dir).join("manifest.json").is_file() {
            builder = builder.artifacts_dir(dir).warmup(true);
        } else {
            eprintln!("note: {dir}/manifest.json missing — using sparse executor");
        }
    }
    let engine = match p.get("data-dir") {
        Some(dir) => {
            let cfg = DurabilityConfig::new(dir)
                .sync(p.req_parse::<SyncPolicy>("durability")?)
                .checkpoint_every(p.req_parse::<u64>("checkpoint-every")?);
            let (engine, report) = builder.durability(cfg).build_durable(edges)?;
            match report.snapshot_loaded {
                Some(seq) => println!(
                    "recovered: checkpoint@{seq} + {} wal batches ({} ops){}{}{}",
                    report.replayed_batches,
                    report.replayed_ops,
                    if report.clean_shutdown { " [clean shutdown]" } else { "" },
                    if report.torn_tail_discarded { " [torn wal tail discarded]" } else { "" },
                    if report.snapshots_skipped > 0 {
                        format!(" [{} corrupt snapshot(s) skipped]", report.snapshots_skipped)
                    } else {
                        String::new()
                    },
                ),
                None if report.replayed_batches > 0 => println!(
                    "recovered: no checkpoint; replayed {} wal batches ({} ops)",
                    report.replayed_batches, report.replayed_ops
                ),
                None => println!("durability on: fresh data dir {dir}"),
            }
            engine
        }
        None => builder.build_from_edges(edges)?,
    };
    println!(
        "engine ready: |V|={}, |E|={}, xla={}",
        engine.graph().num_vertices(),
        engine.graph().num_edges(),
        engine.has_xla()
    );
    let handle = ServerHandle::spawn_with(engine, &opts);
    serve_tcp_with(handle, p.get("addr").unwrap(), opts)
}

fn initial_edges(p: &veilgraph::util::cli::Parsed) -> Result<Vec<(u64, u64)>> {
    if let Some(path) = p.get("graph") {
        return load_edges(path);
    }
    if let Some(name) = p.get("dataset") {
        let spec = dataset_by_name(name)
            .ok_or_else(|| Error::Usage(format!("unknown dataset {name:?}")))?;
        return Ok(spec.generate(p.req_parse::<f64>("scale")?));
    }
    Ok(Vec::new())
}

fn cmd_generate(args: &[String]) -> Result<()> {
    let cmd = Command::new("generate", "emit a synthetic dataset stand-in as TSV")
        .opt("dataset", "stand-in name (see `figures --table1`)", Some("web-cnr"))
        .opt("scale", "scale factor (1.0 = DESIGN.md Table 1b)", Some("0.1"))
        .opt("out", "output TSV path", None)
        .flag("help", "show usage");
    let p = cmd.parse(args)?;
    if p.flag("help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let name = p.get("dataset").unwrap();
    let scale = p.req_parse::<f64>("scale")?;
    let spec =
        dataset_by_name(name).ok_or_else(|| Error::Usage(format!("unknown dataset {name:?}")))?;
    let edges = spec.generate(scale);
    let header = format!(
        "VeilGraph stand-in {} for {} at scale {scale} ({} edges)",
        spec.name,
        spec.paper_name,
        edges.len()
    );
    match p.get("out") {
        Some(path) => {
            save_edges(path, &edges, Some(&header))?;
            println!("wrote {} edges to {path}", edges.len());
        }
        None => {
            let mut out = Vec::new();
            veilgraph::graph::io::write_edges(&mut out, &edges, Some(&header))?;
            print!("{}", String::from_utf8_lossy(&out));
        }
    }
    Ok(())
}

fn harness_from(p: &veilgraph::util::cli::Parsed) -> Result<HarnessConfig> {
    Ok(HarnessConfig {
        q: p.req_parse::<usize>("queries")?,
        pagerank: PageRankConfig {
            beta: p.req_parse::<f64>("beta")?,
            epsilon: 1e-8,
            max_iters: 100,
            dangling_redistribution: false,
            normalized: false,
            warm_start_exact: true,
            parallelism: p.req_parse::<usize>("parallelism")?,
        },
        seed: p.req_parse::<u64>("seed")?,
        workers: p.req_parse::<usize>("workers")?,
        ..Default::default()
    })
}

fn cmd_experiment(args: &[String]) -> Result<()> {
    let cmd = Command::new("experiment", "run the paper protocol for one dataset")
        .opt("dataset", "stand-in name", Some("social-enron"))
        .opt("scale", "dataset scale factor", Some("0.1"))
        .opt("queries", "queries per stream (paper: 50)", Some("50"))
        .opt("beta", "PageRank damping factor", Some("0.85"))
        .opt("seed", "stream sampling seed", Some("7"))
        .opt("workers", "parallel combination replays", Some("8"))
        .opt(
            "parallelism",
            "PageRank shards (1 = serial, 0 = auto; clamped so workers*shards <= cores)",
            Some("1"),
        )
        .opt("out", "results directory", Some("results"))
        .flag("help", "show usage");
    let p = cmd.parse(args)?;
    if p.flag("help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let name = p.get("dataset").unwrap().to_string();
    let spec = dataset_by_name(&name)
        .ok_or_else(|| Error::Usage(format!("unknown dataset {name:?}")))?;
    let scale = p.req_parse::<f64>("scale")?;
    let cfg = harness_from(&p)?;
    let sw = veilgraph::util::timer::Stopwatch::start();
    let edges = spec.generate(scale);
    let result =
        run_experiment(&name, &edges, spec.stream_len_at(scale), spec.shuffled, &cfg)?;
    let files = write_experiment(p.get("out").unwrap(), &result)?;
    let (speedup, rbo) = headline(&result);
    println!("experiment {name} done in {}", fmt_duration(sw.secs()));
    println!("  best-speedup combo: {speedup:.2}x at RBO {rbo:.4}");
    println!("  wrote: {}", files.join(", "));
    for fig in figures_for_dataset(&name) {
        println!("{}", render_figure(&fig, &result));
    }
    Ok(())
}

fn cmd_figures(args: &[String]) -> Result<()> {
    let cmd = Command::new("figures", "regenerate paper figures")
        .opt("fig", "single figure number (3-30)", None)
        .opt("scale", "dataset scale factor", Some("0.1"))
        .opt("queries", "queries per stream", Some("50"))
        .opt("beta", "PageRank damping factor", Some("0.85"))
        .opt("seed", "stream sampling seed", Some("7"))
        .opt("workers", "parallel combination replays", Some("8"))
        .opt(
            "parallelism",
            "PageRank shards (1 = serial, 0 = auto; clamped so workers*shards <= cores)",
            Some("1"),
        )
        .opt("out", "results directory", Some("results"))
        .flag("all", "run every dataset (Figs. 3-30)")
        .flag("table1", "print Table 1 (datasets) and exit")
        .flag("help", "show usage");
    let p = cmd.parse(args)?;
    if p.flag("help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let scale = p.req_parse::<f64>("scale")?;
    if p.flag("table1") {
        println!("{}", table1(scale));
        return Ok(());
    }
    let cfg = harness_from(&p)?;
    let datasets: Vec<_> = if let Some(n) = p.get_parse::<u32>("fig")? {
        let fig = figure_by_number(n)
            .ok_or_else(|| Error::Usage(format!("figure {n} out of range 3-30")))?;
        vec![dataset_by_name(fig.dataset).unwrap()]
    } else if p.flag("all") {
        all_datasets()
    } else {
        return Err(Error::Usage("pass --fig N or --all (or --table1)".into()));
    };
    for spec in datasets {
        let sw = veilgraph::util::timer::Stopwatch::start();
        let edges = spec.generate(scale);
        let result =
            run_experiment(spec.name, &edges, spec.stream_len_at(scale), spec.shuffled, &cfg)?;
        write_experiment(p.get("out").unwrap(), &result)?;
        let (speedup, rbo) = headline(&result);
        println!(
            "{}: {} figures written in {} (best speedup {speedup:.2}x @ RBO {rbo:.4})",
            spec.name,
            figures_for_dataset(spec.name).len(),
            fmt_duration(sw.secs())
        );
        if let Some(n) = p.get_parse::<u32>("fig")? {
            let fig = figure_by_number(n).unwrap();
            println!("{}", render_figure(&fig, &result));
        }
    }
    Ok(())
}

fn cmd_info(args: &[String]) -> Result<()> {
    let cmd = Command::new("info", "artifact/platform diagnostics")
        .opt("artifacts", "artifacts directory", Some("artifacts"))
        .flag("help", "show usage");
    let p = cmd.parse(args)?;
    if p.flag("help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let dir = p.get("artifacts").unwrap();
    match veilgraph::runtime::client::XlaRuntime::new(dir) {
        Ok(rt) => {
            println!("platform: {}", rt.platform());
            println!("iters_fused: {}", rt.iters_fused());
            println!("artifacts:");
            for e in &rt.manifest().entries {
                println!("  {:<28} variant={:?} capacity={}", e.name, e.variant, e.capacity);
            }
        }
        Err(e) => println!("runtime unavailable: {e}"),
    }
    Ok(())
}
