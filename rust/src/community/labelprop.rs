//! Label propagation community detection (Raghavan et al. 2007) over a
//! CSR snapshot — the exact baseline for the streaming variant.
//!
//! Semi-synchronous: each sweep, every vertex adopts the most frequent
//! label among its (in + out) neighbors, ties broken toward the smaller
//! label so the algorithm is deterministic and convergent. The paper
//! names “greedy clustering methods” and “maintaining online communities
//! updated” as targets of the VeilGraph model (§3.1, §7); this module +
//! [`crate::community::streaming`] realize that extension.

use crate::graph::dynamic::DynamicGraph;
use crate::graph::VertexIdx;

/// Result of a label-propagation run.
#[derive(Clone, Debug)]
pub struct Communities {
    /// Community label per dense vertex index (labels are vertex indices
    /// of community "seeds"; stable across runs).
    pub labels: Vec<u32>,
    /// Sweeps executed.
    pub sweeps: usize,
    /// Labels changed in the final sweep (0 ⇔ converged).
    pub last_changes: usize,
}

impl Communities {
    /// Number of distinct communities.
    pub fn num_communities(&self) -> usize {
        let mut set: Vec<u32> = self.labels.clone();
        set.sort_unstable();
        set.dedup();
        set.len()
    }

    /// Members of the community containing `v`.
    pub fn community_of(&self, v: VertexIdx) -> Vec<VertexIdx> {
        let l = self.labels[v as usize];
        (0..self.labels.len() as u32).filter(|&u| self.labels[u as usize] == l).collect()
    }
}

/// Most frequent neighbor label; ties toward the smaller label; `None`
/// for isolated vertices.
fn dominant_label(g: &DynamicGraph, v: VertexIdx, labels: &[u32]) -> Option<u32> {
    let mut counts: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
    for &w in g.out_neighbors(v).iter().chain(g.in_neighbors(v)) {
        *counts.entry(labels[w as usize]).or_default() += 1;
    }
    counts
        .into_iter()
        .max_by(|a, b| a.1.cmp(&b.1).then(b.0.cmp(&a.0))) // max count, min label
        .map(|(l, _)| l)
}

/// Run label propagation from singleton labels until stable (or
/// `max_sweeps`).
pub fn label_propagation(g: &DynamicGraph, max_sweeps: usize) -> Communities {
    let n = g.num_vertices();
    let labels: Vec<u32> = (0..n as u32).collect();
    label_propagation_from(g, labels, max_sweeps)
}

/// Run label propagation from a warm-start labeling (the streaming
/// variant seeds with the previous measurement point's labels).
pub fn label_propagation_from(
    g: &DynamicGraph,
    mut labels: Vec<u32>,
    max_sweeps: usize,
) -> Communities {
    let n = g.num_vertices();
    assert_eq!(labels.len(), n, "label vector length mismatch");
    let mut sweeps = 0;
    let mut last_changes = 0;
    for _ in 0..max_sweeps {
        sweeps += 1;
        last_changes = 0;
        // deterministic order; semi-synchronous (reads see this sweep's
        // earlier writes, which accelerates convergence and keeps ties
        // stable)
        for v in 0..n as u32 {
            if let Some(l) = dominant_label(g, v, &labels) {
                if labels[v as usize] != l {
                    labels[v as usize] = l;
                    last_changes += 1;
                }
            }
        }
        if last_changes == 0 {
            break;
        }
    }
    Communities { labels, sweeps, last_changes }
}

/// Restricted sweep: only vertices in `active` may change labels; the
/// rest are frozen (the summarized/streaming update step).
pub fn label_propagation_restricted(
    g: &DynamicGraph,
    mut labels: Vec<u32>,
    active: &[VertexIdx],
    max_sweeps: usize,
) -> Communities {
    let mut sweeps = 0;
    let mut last_changes = 0;
    for _ in 0..max_sweeps {
        sweeps += 1;
        last_changes = 0;
        for &v in active {
            if let Some(l) = dominant_label(g, v, &labels) {
                if labels[v as usize] != l {
                    labels[v as usize] = l;
                    last_changes += 1;
                }
            }
        }
        if last_changes == 0 {
            break;
        }
    }
    Communities { labels, sweeps, last_changes }
}

/// Agreement between two labelings: fraction of vertex *pairs* (sampled)
/// on which they agree about co-membership — a cheap Rand-index estimate
/// used to score streaming communities against the exact baseline.
pub fn pair_agreement(a: &[u32], b: &[u32], samples: usize, seed: u64) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 1.0;
    }
    let mut rng = crate::util::rng::Xoshiro256pp::new(seed);
    let mut agree = 0usize;
    for _ in 0..samples {
        let i = rng.range(0, n);
        let j = rng.range(0, n);
        if i == j {
            agree += 1;
            continue;
        }
        let same_a = a[i] == a[j];
        let same_b = b[i] == b[j];
        if same_a == same_b {
            agree += 1;
        }
    }
    agree as f64 / samples as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two triangles joined by one weak edge.
    fn two_triangles() -> DynamicGraph {
        DynamicGraph::from_edges(vec![
            (0, 1), (1, 0), (1, 2), (2, 1), (2, 0), (0, 2), // triangle A
            (3, 4), (4, 3), (4, 5), (5, 4), (5, 3), (3, 5), // triangle B
            (2, 3), // weak bridge
        ])
        .0
    }

    #[test]
    fn finds_the_two_triangles() {
        let g = two_triangles();
        let c = label_propagation(&g, 50);
        assert_eq!(c.last_changes, 0, "must converge");
        assert_eq!(c.labels[0], c.labels[1]);
        assert_eq!(c.labels[1], c.labels[2]);
        assert_eq!(c.labels[3], c.labels[4]);
        assert_eq!(c.labels[4], c.labels[5]);
        assert_ne!(c.labels[0], c.labels[3], "triangles must stay separate");
        assert_eq!(c.num_communities(), 2);
        assert_eq!(c.community_of(0).len(), 3);
    }

    #[test]
    fn is_deterministic() {
        let g = two_triangles();
        assert_eq!(label_propagation(&g, 50).labels, label_propagation(&g, 50).labels);
    }

    #[test]
    fn warm_start_at_fixed_point_is_noop() {
        let g = two_triangles();
        let c = label_propagation(&g, 50);
        let c2 = label_propagation_from(&g, c.labels.clone(), 50);
        assert_eq!(c2.sweeps, 1);
        assert_eq!(c2.labels, c.labels);
    }

    #[test]
    fn restricted_sweep_freezes_inactive() {
        let g = two_triangles();
        let init: Vec<u32> = (0..6).collect();
        // only vertex 1 may move: it adopts the min label among {0, 2} → 0
        let c = label_propagation_restricted(&g, init.clone(), &[1], 10);
        assert_eq!(c.labels[1], 0);
        for v in [0usize, 2, 3, 4, 5] {
            assert_eq!(c.labels[v], init[v], "frozen vertex {v} moved");
        }
    }

    #[test]
    fn isolated_vertices_keep_their_label() {
        let mut g = two_triangles();
        g.add_vertex(99);
        let c = label_propagation(&g, 50);
        assert_eq!(c.labels[6], 6, "isolated vertex keeps singleton label");
    }

    #[test]
    fn pair_agreement_bounds() {
        let a = vec![0u32, 0, 1, 1];
        assert_eq!(pair_agreement(&a, &a, 500, 1), 1.0);
        let b = vec![0u32, 1, 0, 1];
        let v = pair_agreement(&a, &b, 2000, 1);
        assert!(v < 1.0 && v > 0.0);
    }
}
