//! Streaming communities: the VeilGraph model applied to label
//! propagation (paper §7: “extend and reproduce GraphBolt's techniques to
//! other problems such as maintaining online communities updated”).
//!
//! Reuses the exact same machinery as the PageRank engine — the
//! pending-update buffer with `d_{t-1}` capture and hot-vertex selection
//! (Eqs. 2–5) — but the summarized computation is a *restricted* label
//! propagation: only hot vertices may change labels, the rest are frozen
//! (the big-vertex analogue: frozen vertices contribute their labels but
//! are never recomputed).

use std::collections::HashMap;

use crate::coordinator::udf::Action;
use crate::error::{Error, Result};
use crate::graph::dynamic::DynamicGraph;
use crate::graph::VertexId;
use crate::community::labelprop::{
    label_propagation, label_propagation_from, label_propagation_restricted, Communities,
};
use crate::stream::buffer::UpdateBuffer;
use crate::stream::event::EdgeOp;
use crate::summary::hot::{compute_hot_set, HotSetInputs};
use crate::summary::params::SummaryParams;
use crate::util::timer::Stopwatch;

/// A community query result.
#[derive(Clone, Debug)]
pub struct CommunityResult {
    pub query_id: u64,
    pub action: Action,
    /// Label per dense index.
    pub labels: Vec<u32>,
    /// |K| recomputed this query (0 for exact/repeat).
    pub hot_vertices: usize,
    pub elapsed_secs: f64,
    pub sweeps: usize,
}

/// Streaming community engine (VeilGraph model, label-propagation
/// algorithm).
pub struct StreamingCommunities {
    graph: DynamicGraph,
    buffer: UpdateBuffer,
    params: SummaryParams,
    max_sweeps: usize,
    labels: Vec<u32>,
    /// Degree-change scores stand in for ranks in Eq. 5: we use the
    /// previous labels' community sizes as the “score” signal so bigger
    /// communities expand further (documented deviation; PageRank uses
    /// ranks).
    community_size: Vec<f64>,
    carry_prev_degree: HashMap<VertexId, usize>,
    carry_new_vertices: Vec<VertexId>,
    query_count: u64,
}

impl StreamingCommunities {
    /// Build over an initial edge list; runs the initial exact label
    /// propagation (measurement point 0).
    pub fn new(
        edges: impl IntoIterator<Item = (VertexId, VertexId)>,
        params: SummaryParams,
        max_sweeps: usize,
    ) -> Result<Self> {
        let (graph, _) = DynamicGraph::from_edges(edges);
        let initial = label_propagation(&graph, max_sweeps);
        let mut s = Self {
            graph,
            buffer: UpdateBuffer::new(),
            params,
            max_sweeps,
            labels: initial.labels,
            community_size: Vec::new(),
            carry_prev_degree: HashMap::new(),
            carry_new_vertices: Vec::new(),
            query_count: 0,
        };
        s.refresh_scores();
        Ok(s)
    }

    fn refresh_scores(&mut self) {
        let n = self.graph.num_vertices();
        let mut counts: HashMap<u32, f64> = HashMap::new();
        for &l in &self.labels {
            *counts.entry(l).or_default() += 1.0;
        }
        // Relative community size ∈ (0, 1]: keeps Eq. 5's radius in the
        // same O(1)-score regime the unnormalized PageRank calibrates for.
        self.community_size = (0..n)
            .map(|v| counts.get(&self.labels[v]).copied().unwrap_or(1.0) / n.max(1) as f64)
            .collect();
    }

    /// Ingest one operation.
    pub fn ingest(&mut self, op: EdgeOp) {
        self.buffer.register(op);
    }

    /// The current graph.
    pub fn graph(&self) -> &DynamicGraph {
        &self.graph
    }

    /// Current labels.
    pub fn labels(&self) -> &[u32] {
        &self.labels
    }

    /// Serve a query with the given action (the caller's UDF decides —
    /// kept explicit here to mirror Alg. 1 without duplicating the
    /// PageRank engine's policy plumbing).
    pub fn query(&mut self, action: Action) -> Result<CommunityResult> {
        let sw = Stopwatch::start();
        self.query_count += 1;
        if !self.buffer.is_empty() {
            let applied = self.buffer.apply(&mut self.graph)?;
            for (id, d) in applied.prev_degree {
                if !self.carry_prev_degree.contains_key(&id)
                    && !self.carry_new_vertices.contains(&id)
                {
                    self.carry_prev_degree.insert(id, d);
                }
            }
            for id in applied.new_vertices {
                if !self.carry_new_vertices.contains(&id) {
                    self.carry_new_vertices.push(id);
                }
            }
        }
        // new vertices start as their own singleton community
        let n = self.graph.num_vertices();
        if self.labels.len() < n {
            for v in self.labels.len()..n {
                self.labels.push(v as u32);
            }
            self.refresh_scores();
        }
        let result = match action {
            Action::RepeatLast => Communities {
                labels: self.labels.clone(),
                sweeps: 0,
                last_changes: 0,
            },
            Action::ComputeExact => {
                let c = label_propagation_from(&self.graph, self.labels.clone(), self.max_sweeps);
                self.carry_prev_degree.clear();
                self.carry_new_vertices.clear();
                c
            }
            Action::ComputeApproximate => {
                let inputs = HotSetInputs {
                    graph: &self.graph,
                    prev_degree: &self.carry_prev_degree,
                    new_vertices: &self.carry_new_vertices,
                    prev_ranks: &self.community_size,
                };
                let hot = compute_hot_set(&inputs, &self.params);
                let active = hot.all();
                self.carry_prev_degree.clear();
                self.carry_new_vertices.clear();
                let mut c = label_propagation_restricted(
                    &self.graph,
                    self.labels.clone(),
                    &active,
                    self.max_sweeps,
                );
                c.sweeps = c.sweeps.min(self.max_sweeps);
                if c.labels.len() != n {
                    return Err(Error::Engine("label vector desync".into()));
                }
                self.labels = c.labels.clone();
                self.refresh_scores();
                return Ok(CommunityResult {
                    query_id: self.query_count,
                    action,
                    labels: self.labels.clone(),
                    hot_vertices: active.len(),
                    elapsed_secs: sw.secs(),
                    sweeps: c.sweeps,
                });
            }
        };
        self.labels = result.labels.clone();
        self.refresh_scores();
        Ok(CommunityResult {
            query_id: self.query_count,
            action,
            labels: result.labels,
            hot_vertices: 0,
            elapsed_secs: sw.secs(),
            sweeps: result.sweeps,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::community::labelprop::pair_agreement;
    use crate::graph::generate;

    fn params() -> SummaryParams {
        SummaryParams::new(0.1, 1, 0.1)
    }

    #[test]
    fn initial_communities_match_exact() {
        let edges = generate::ego_network(300, 40, 0.4, 4, 3);
        let sc = StreamingCommunities::new(edges.iter().copied(), params(), 30).unwrap();
        let (g, _) = DynamicGraph::from_edges(edges.iter().copied());
        let exact = label_propagation(&g, 30);
        assert_eq!(sc.labels(), &exact.labels[..]);
    }

    #[test]
    fn approximate_updates_track_exact_recompute() {
        let edges = generate::ego_network(400, 50, 0.4, 4, 7);
        let mut stream_engine =
            StreamingCommunities::new(edges.iter().copied(), params(), 30).unwrap();
        let mut exact_engine =
            StreamingCommunities::new(edges.iter().copied(), params(), 30).unwrap();
        for batch in 0..5u64 {
            for i in 0..20u64 {
                let op = EdgeOp::add(1000 + batch * 20 + i, i % 50);
                stream_engine.ingest(op);
                exact_engine.ingest(op);
            }
            let a = stream_engine.query(Action::ComputeApproximate).unwrap();
            let e = exact_engine.query(Action::ComputeExact).unwrap();
            assert!(a.hot_vertices > 0, "updates must produce hot vertices");
            let agree = pair_agreement(&a.labels, &e.labels, 20_000, batch);
            assert!(agree > 0.9, "batch {batch}: agreement {agree}");
            // the approximate engine must not have touched most labels
            assert!(a.hot_vertices < stream_engine.graph().num_vertices() / 2);
        }
    }

    #[test]
    fn repeat_last_returns_cached_labels() {
        let edges = generate::ego_network(200, 30, 0.4, 3, 9);
        let mut sc = StreamingCommunities::new(edges.iter().copied(), params(), 30).unwrap();
        let before = sc.labels().to_vec();
        sc.ingest(EdgeOp::add(500, 0));
        let r = sc.query(Action::RepeatLast).unwrap();
        assert_eq!(r.sweeps, 0);
        // new vertex got a singleton label appended; old labels unchanged
        assert_eq!(&r.labels[..before.len()], &before[..]);
        assert_eq!(r.labels.len(), before.len() + 1);
    }

    #[test]
    fn new_vertices_join_communities_via_approximate() {
        let edges = generate::ego_network(200, 30, 0.5, 3, 11);
        let mut sc = StreamingCommunities::new(edges.iter().copied(), params(), 30).unwrap();
        // attach a new vertex firmly to the core
        for t in 0..5u64 {
            sc.ingest(EdgeOp::add(999, t));
            sc.ingest(EdgeOp::add(t, 999));
        }
        let r = sc.query(Action::ComputeApproximate).unwrap();
        let idx = sc.graph().index(999).unwrap() as usize;
        let core_label = r.labels[0];
        assert_eq!(r.labels[idx], core_label, "new vertex must join the core community");
    }
}
