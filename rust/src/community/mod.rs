//! Community detection on the VeilGraph model (paper §7 future work):
//! exact label propagation plus the streaming/summarized variant that
//! restricts recomputation to the hot-vertex set.

pub mod labelprop;
pub mod streaming;
