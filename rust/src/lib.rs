//! # VeilGraph — streaming graph approximations
//!
//! A Rust + JAX + Pallas reproduction of *“VeilGraph: Streaming Graph
//! Approximations”* (Coimbra, Rosa, Esteves, Francisco, Veiga, 2018 —
//! originally published as *GraphBolt*; see DESIGN.md for the identity
//! note).
//!
//! VeilGraph processes a stream of graph updates and serves approximate
//! graph-algorithm results (PageRank as the case study) by restricting
//! recomputation to a set of **hot vertices** `K = K_r ∪ K_n ∪ K_Δ` and a
//! **summary graph** in which a single *big vertex* `B` aggregates every
//! non-hot vertex.
//!
//! ## Layer map
//!
//! * **L3 (this crate)** — stream ingestion, update statistics, hot-vertex
//!   selection, summary construction, the Alg.-1 UDF pipeline, query
//!   serving, metrics and the experiment harness.
//! * **Runtime** — [`runtime`] loads AOT-compiled HLO-text artifacts
//!   (produced once by `python/compile/aot.py`) and executes them through
//!   PJRT via the `xla` crate. Python never runs on the request path.
//! * **L2/L1** — the summarized PageRank iteration itself: a JAX model
//!   wrapping a Pallas kernel (`python/compile/`), lowered per capacity.
//!
//! ## Quick start
//!
//! ```no_run
//! use veilgraph::prelude::*;
//!
//! let mut engine = EngineBuilder::new()
//!     .params(SummaryParams::new(0.2, 1, 0.5))
//!     .build_from_edges(vec![(0, 1), (1, 2), (2, 0)])
//!     .unwrap();
//! engine.ingest(EdgeOp::add(0, 2));
//! let result = engine.query().unwrap();
//! println!("top vertex = {:?}", result.top(1));
//! ```

pub mod bench;
pub mod community;
pub mod coordinator;
pub mod error;
pub mod experiments;
pub mod graph;
pub mod metrics;
pub mod pagerank;
pub mod runtime;
pub mod stream;
pub mod summary;
pub mod testing;
pub mod util;

/// Convenience re-exports of the most commonly used public items.
pub mod prelude {
    pub use crate::coordinator::checkpoint::{DurabilityConfig, RecoveryReport};
    pub use crate::coordinator::engine::{Engine, EngineBuilder, QueryResult};
    pub use crate::coordinator::protocol::{Envelope, Request, Response};
    pub use crate::coordinator::wal::{DurabilityStats, SyncPolicy};
    pub use crate::coordinator::serving::{RankSnapshot, SnapshotReader};
    pub use crate::coordinator::subscription::{
        Mailbox, Notification, Subscription, SubscriptionRegistry,
    };
    pub use crate::coordinator::udf::{Action, UdfSuite};
    pub use crate::error::{Error, Result};
    pub use crate::graph::csr::Csr;
    pub use crate::graph::dynamic::DynamicGraph;
    pub use crate::pagerank::power::{PageRank, PageRankConfig};
    pub use crate::runtime::executor::{Backend, SummarizedExecutor};
    pub use crate::stream::event::{EdgeOp, UpdateEvent};
    pub use crate::stream::window::SlidingWindow;
    pub use crate::summary::params::SummaryParams;
}
