//! Engine metrics registry: counters, gauges and timing series exposed to
//! the `OnQueryResult` UDF (the paper gives it “execution statistics
//! (such as total execution time, physical space, network traffic …)”).

use std::collections::BTreeMap;

use crate::util::json::Json;
use crate::util::stats::Moments;

/// A process-local metrics registry.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    timings: BTreeMap<String, Moments>,
}

impl MetricsRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment a counter.
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_default() += by;
    }

    /// Set a gauge.
    pub fn set(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    /// Record a timing observation (seconds).
    pub fn time(&mut self, name: &str, secs: f64) {
        self.timings.entry(name.to_string()).or_default().push(secs);
    }

    /// Counter value (0 if absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Gauge value.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Timing moments for a series.
    pub fn timing(&self, name: &str) -> Option<&Moments> {
        self.timings.get(name)
    }

    /// Export everything as JSON (for the server's `stats` command and
    /// experiment reports).
    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters.iter().map(|(k, &v)| (k.clone(), Json::Num(v as f64))).collect(),
        );
        let gauges =
            Json::Obj(self.gauges.iter().map(|(k, &v)| (k.clone(), Json::Num(v))).collect());
        let timings = Json::Obj(
            self.timings
                .iter()
                .map(|(k, m)| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("count", Json::Num(m.count() as f64)),
                            ("mean", Json::Num(m.mean())),
                            ("stddev", Json::Num(m.stddev())),
                            ("min", Json::Num(if m.count() == 0 { 0.0 } else { m.min() })),
                            ("max", Json::Num(if m.count() == 0 { 0.0 } else { m.max() })),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![("counters", counters), ("gauges", gauges), ("timings", timings)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = MetricsRegistry::new();
        m.inc("queries", 1);
        m.inc("queries", 2);
        assert_eq!(m.counter("queries"), 3);
        assert_eq!(m.counter("absent"), 0);
    }

    #[test]
    fn gauges_overwrite() {
        let mut m = MetricsRegistry::new();
        m.set("k_ratio", 0.1);
        m.set("k_ratio", 0.2);
        assert_eq!(m.gauge("k_ratio"), Some(0.2));
    }

    #[test]
    fn timings_track_moments() {
        let mut m = MetricsRegistry::new();
        m.time("query", 1.0);
        m.time("query", 3.0);
        let t = m.timing("query").unwrap();
        assert_eq!(t.count(), 2);
        assert!((t.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn json_export_roundtrips() {
        let mut m = MetricsRegistry::new();
        m.inc("a", 5);
        m.set("g", 1.5);
        m.time("t", 0.25);
        let j = m.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("counters").unwrap().get("a").unwrap().as_u64(), Some(5));
        assert_eq!(
            parsed.get("timings").unwrap().get("t").unwrap().get("count").unwrap().as_u64(),
            Some(1)
        );
    }
}
