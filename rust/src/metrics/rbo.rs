//! Rank-Biased Overlap (Webber, Moffat & Zobel 2010) — the paper's
//! accuracy metric (§5.2).
//!
//! RBO compares two (possibly truncated, possibly different-length)
//! rankings, weighting agreement at high ranks more heavily; parameter
//! `p ∈ (0,1)` sets how steeply weights decay (the expected evaluation
//! depth is `1/(1-p)`). We implement `RBO_EXT` (the paper's Eq. 32):
//! extrapolation of the overlap seen in the evaluated prefix to infinite
//! depth, which is the standard point estimate.

use std::collections::HashSet;

/// Extrapolated RBO between two rankings (ids at decreasing relevance).
///
/// Handles uneven lengths per Webber §4.3. Returns a value in [0, 1]:
/// 1 ⇔ identical prefixes, 0 ⇔ disjoint.
pub fn rbo_ext<T: std::hash::Hash + Eq + Copy>(list_s: &[T], list_l: &[T], p: f64) -> f64 {
    assert!((0.0..1.0).contains(&p) && p > 0.0, "p must be in (0,1)");
    // s = shorter length, l = longer
    let (short, long) =
        if list_s.len() <= list_l.len() { (list_s, list_l) } else { (list_l, list_s) };
    let s = short.len();
    let l = long.len();
    if l == 0 {
        return 1.0; // two empty rankings agree vacuously
    }
    if s == 0 {
        return 0.0;
    }

    let mut seen_short: HashSet<T> = HashSet::with_capacity(s);
    let mut seen_long: HashSet<T> = HashSet::with_capacity(l);
    let mut x = 0usize; // overlap |S ∩ L| at depth d
    let mut x_s = 0usize; // overlap at depth s (fixed once d > s)
    let mut sum1 = 0.0; // Σ_{d=1}^{l} X_d/d · p^d
    let mut sum2 = 0.0; // Σ_{d=s+1}^{l} X_s·(d-s)/(s·d) · p^d
    let mut pd = 1.0; // p^d, updated incrementally

    for d in 1..=l {
        pd *= p;
        if d <= s {
            let a = short[d - 1];
            let b = long[d - 1];
            if a == b {
                x += 1;
            } else {
                if seen_long.contains(&a) {
                    x += 1;
                }
                if seen_short.contains(&b) {
                    x += 1;
                }
                seen_short.insert(a);
                seen_long.insert(b);
            }
            if d == s {
                x_s = x;
            }
        } else {
            let b = long[d - 1];
            if seen_short.contains(&b) {
                x += 1;
            }
            sum2 += x_s as f64 * (d - s) as f64 / (s as f64 * d as f64) * pd;
        }
        sum1 += x as f64 / d as f64 * pd;
    }
    let x_l = x;
    let p_l = p.powi(l as i32);
    let ext = ((x_l - x_s) as f64 / l as f64 + x_s as f64 / s as f64) * p_l;
    ((1.0 - p) / p * (sum1 + sum2) + ext).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_lists_give_one() {
        let a = [1u64, 2, 3, 4, 5];
        let v = rbo_ext(&a, &a, 0.9);
        assert!((v - 1.0).abs() < 1e-12, "{v}");
    }

    #[test]
    fn disjoint_lists_give_zero() {
        let a = [1u64, 2, 3];
        let b = [4u64, 5, 6];
        let v = rbo_ext(&a, &b, 0.9);
        assert!(v.abs() < 1e-12, "{v}");
    }

    #[test]
    fn higher_ranks_weigh_more() {
        let base = [1u64, 2, 3, 4, 5, 6, 7, 8, 9, 10];
        // swap at the top vs swap at the bottom
        let mut top = base;
        top.swap(0, 1);
        let mut bottom = base;
        bottom.swap(8, 9);
        let v_top = rbo_ext(&base, &top, 0.9);
        let v_bottom = rbo_ext(&base, &bottom, 0.9);
        assert!(v_top < v_bottom, "top {v_top} vs bottom {v_bottom}");
    }

    #[test]
    fn symmetric_in_arguments() {
        let a = [1u64, 2, 3, 4, 5, 6];
        let b = [2u64, 1, 3, 7, 8];
        let v1 = rbo_ext(&a, &b, 0.95);
        let v2 = rbo_ext(&b, &a, 0.95);
        assert!((v1 - v2).abs() < 1e-12);
    }

    #[test]
    fn uneven_lengths_with_identical_prefix_stay_high() {
        let long: Vec<u64> = (0..100).collect();
        let short: Vec<u64> = (0..50).collect();
        let v = rbo_ext(&short, &long, 0.98);
        assert!(v > 0.95, "prefix agreement should extrapolate high, got {v}");
    }

    #[test]
    fn known_value_two_element_example() {
        // S = [a, b], L = [b, a]: X_1 = 0, X_2 = 2.
        // RBO_EXT = (1-p)/p * (0·p/1 + 2/2·p²) + (2/2)·p² — with s = l = 2:
        // = (1-p)·p + p². For p = 0.5: 0.25 + 0.25 = 0.5.
        let v = rbo_ext(&[1u64, 2], &[2u64, 1], 0.5);
        assert!((v - 0.5).abs() < 1e-12, "{v}");
    }

    #[test]
    fn partial_overlap_monotone_in_p_depth_weighting() {
        // deeper-biased p (larger) should value the long agreeing tail more
        let a: Vec<u64> = (0..200).collect();
        let mut b = a.clone();
        b.swap(0, 1); // disagreement only at the very top
        let shallow = rbo_ext(&a, &b, 0.8);
        let deep = rbo_ext(&a, &b, 0.995);
        assert!(deep > shallow);
    }

    #[test]
    fn empty_cases() {
        let e: [u64; 0] = [];
        assert_eq!(rbo_ext(&e, &e, 0.9), 1.0);
        assert_eq!(rbo_ext(&e, &[1u64, 2], 0.9), 0.0);
    }

    #[test]
    fn duplicate_free_assumption_holds_on_clamp() {
        // even adversarial input stays within [0,1]
        let a = [1u64, 1, 1];
        let b = [1u64, 2, 3];
        let v = rbo_ext(&a, &b, 0.9);
        assert!((0.0..=1.0).contains(&v));
    }
}
