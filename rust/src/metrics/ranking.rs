//! Rank-list utilities: top-k extraction and the paper's truncation rule.
//!
//! §5.2: “for an update density lower or equal to 200 edges per update,
//! we used the top 1000 ranks. Above the 200 edge density, we used the
//! top 4000 ranks.”

use crate::graph::VertexId;

/// Dense positions of the top-k entries by score, descending; ties break
/// by ascending id so rankings are deterministic. This is the selection
/// primitive behind [`top_k_ids`] and the published-snapshot top-K index
/// ([`crate::coordinator::serving::RankSnapshot`]) — O(n + k log k), no
/// auxiliary maps.
pub fn top_k_indices(ids: &[VertexId], scores: &[f64], k: usize) -> Vec<usize> {
    assert_eq!(ids.len(), scores.len());
    let mut order: Vec<usize> = (0..ids.len()).collect();
    let k = k.min(ids.len());
    if k == 0 {
        return Vec::new();
    }
    // Partial selection then sort of the prefix — O(n + k log k).
    order.select_nth_unstable_by(k - 1, |&a, &b| {
        scores[b].partial_cmp(&scores[a]).unwrap().then(ids[a].cmp(&ids[b]))
    });
    order.truncate(k);
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap().then(ids[a].cmp(&ids[b])));
    order
}

/// Extract the top-k vertex ids by score, descending; ties break by
/// ascending id so rankings are deterministic.
pub fn top_k_ids(ids: &[VertexId], scores: &[f64], k: usize) -> Vec<VertexId> {
    top_k_indices(ids, scores, k).into_iter().map(|i| ids[i]).collect()
}

/// The paper's RBO truncation depth as a function of update density
/// (edges per query).
pub fn rbo_depth_for_density(edges_per_query: f64) -> usize {
    if edges_per_query <= 200.0 {
        1000
    } else {
        4000
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_orders_by_score_then_id() {
        let ids = [10u64, 20, 30, 40];
        let scores = [0.1, 0.9, 0.9, 0.5];
        assert_eq!(top_k_ids(&ids, &scores, 3), vec![20, 30, 40]);
        assert_eq!(top_k_ids(&ids, &scores, 1), vec![20]);
    }

    #[test]
    fn top_k_clamps_to_len() {
        let ids = [1u64, 2];
        let scores = [0.5, 0.6];
        assert_eq!(top_k_ids(&ids, &scores, 10), vec![2, 1]);
    }

    #[test]
    fn top_k_zero_and_empty() {
        assert!(top_k_ids(&[], &[], 5).is_empty());
        let ids = [1u64];
        assert_eq!(top_k_ids(&ids, &[1.0], 0).len(), 0);
    }

    #[test]
    fn top_k_matches_full_sort_on_random_input() {
        use crate::util::rng::Xoshiro256pp;
        let mut rng = Xoshiro256pp::new(5);
        let n = 500;
        let ids: Vec<u64> = (0..n as u64).collect();
        let scores: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let got = top_k_ids(&ids, &scores, 50);
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b)));
        let want: Vec<u64> = order[..50].iter().map(|&i| ids[i]).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn top_k_indices_agree_with_ids() {
        let ids = [10u64, 20, 30, 40];
        let scores = [0.1, 0.9, 0.9, 0.5];
        assert_eq!(top_k_indices(&ids, &scores, 3), vec![1, 2, 3]);
        let got = top_k_indices(&ids, &scores, 2).into_iter().map(|i| ids[i]).collect::<Vec<_>>();
        assert_eq!(got, top_k_ids(&ids, &scores, 2));
    }

    #[test]
    fn depth_rule_matches_paper() {
        assert_eq!(rbo_depth_for_density(100.0), 1000);
        assert_eq!(rbo_depth_for_density(200.0), 1000);
        assert_eq!(rbo_depth_for_density(400.0), 4000);
        assert_eq!(rbo_depth_for_density(800.0), 4000);
    }
}
