//! Evaluation metrics: Rank-Biased Overlap (accuracy), ranking utilities
//! and the engine's metrics registry.

pub mod ranking;
pub mod rbo;
pub mod registry;
