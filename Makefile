.PHONY: artifacts test bench clean

# AOT-lower the JAX kernels to HLO-text artifacts for the rust runtime.
# Needs python3 with jax (the repo is validated against jax 0.4.37).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts

# Build + full test run with artifacts present, so the runtime
# integration suite (rust/tests/runtime_integration.rs) does not skip.
test: artifacts
	cd rust && cargo build --release && cargo test -q

bench:
	cd rust && cargo bench --bench micro && cargo bench --bench ablation

clean:
	rm -rf rust/target rust/artifacts rust/results results
