"""Pure-jnp oracles for the Pallas kernels and the L2 model.

These are the correctness ground truth: `python/tests/` asserts the Pallas
kernel (interpret mode) and the lowered HLO agree with these to float32
tolerance, and the rust integration tests check the runtime path against
vectors produced by the same formulas.
"""

from __future__ import annotations

import jax.numpy as jnp


def pagerank_step_ref(a, r, b, mask, beta, teleport):
    """r' = mask · (β·(A@r + b) + teleport), all f32."""
    a = a.astype(jnp.float32)
    r = r.astype(jnp.float32)
    b = b.astype(jnp.float32)
    mask = mask.astype(jnp.float32)
    return mask * (beta * (a @ r + b) + teleport)


def pagerank_iterations_ref(a, r, b, mask, beta, teleport, iters: int):
    """`iters` repeated applications of `pagerank_step_ref`."""
    for _ in range(iters):
        r = pagerank_step_ref(a, r, b, mask, beta, teleport)
    return r


def pagerank_run_ref(a, r0, b, mask, beta, teleport, iters: int):
    """Model oracle: final ranks + L1 delta of the last iteration."""
    r_prev = pagerank_iterations_ref(a, r0, b, mask, beta, teleport, iters - 1)
    r_last = pagerank_step_ref(a, r_prev, b, mask, beta, teleport)
    delta = jnp.sum(jnp.abs(r_last - r_prev))
    return r_last, delta
