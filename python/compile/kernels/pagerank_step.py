"""L1 — Pallas kernel for one summarized-PageRank power iteration.

VeilGraph's summarized computation runs PageRank only over the *summary
graph* ``G = (K ∪ {B}, E_K ∪ E_B)`` (paper §3.1).  The rust coordinator
densifies the (small) summary graph into a padded capacity-``C`` problem:

    A[z, u] = val((u, z)) = 1 / d_out(u)   for (u, z) ∈ E_K, else 0
    b[z]    = Σ_{(w,z) ∈ E_B} w_s / d_out(w)     (frozen big-vertex flow)
    mask[z] = 1.0 for z < |K|, else 0.0

and the kernel computes one vertex-centric power-method update

    r'[z] = mask[z] · ( β · (A @ r + b)[z] + (1-β) / n )

where ``n`` is |V| of the *full* graph, so summary ranks stay directly
comparable with full-graph ranks (DESIGN.md §2).

TPU mapping (DESIGN.md §Hardware-Adaptation): the mat-vec is tiled as a
2-D grid of (TILE × TILE) blocks.  Grid dim 0 walks row tiles, grid dim 1
walks column (reduction) tiles; partial sums accumulate into the output
ref, and the affine epilogue (β, teleport, mask) runs on the last column
step.  One A-tile is 128·128·4 B = 64 KiB of VMEM — comfortably inside the
~16 MiB budget with double buffering.  ``interpret=True`` everywhere: the
CPU PJRT plugin cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile edge.  Capacities are multiples of TILE (enforced below).
TILE = 128

# Capacities for which `aot.py` emits artifacts.  Rust picks the smallest
# capacity >= |K| and pads; above the max it falls back to the sparse
# rust-native summarized executor.
CAPACITIES = (128, 256, 512, 1024, 2048)


def _step_kernel(a_ref, r_ref, b_ref, mask_ref, scalars_ref, o_ref):
    """One (row_tile, col_tile) grid step of r' = mask·(β(A@r+b)+(1-β)/n).

    a_ref:      (TILE, TILE) block of A            [VMEM]
    r_ref:      (TILE, 1)    column-tile slice of r [VMEM]
    b_ref:      (TILE, 1)    row-tile slice of b    [VMEM]
    mask_ref:   (TILE, 1)    row-tile slice of mask [VMEM]
    scalars_ref:(1, 2)       [β, (1-β)/n]           [VMEM, broadcast]
    o_ref:      (TILE, 1)    row-tile slice of r'   [VMEM, accumulated]
    """
    k = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Partial mat-vec: (TILE×TILE) @ (TILE×1) — MXU-shaped contraction.
    o_ref[...] += jnp.dot(
        a_ref[...], r_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        beta = scalars_ref[0, 0]
        teleport = scalars_ref[0, 1]
        acc = o_ref[...]
        o_ref[...] = mask_ref[...] * (beta * (acc + b_ref[...]) + teleport)


@functools.partial(jax.jit, static_argnames=("capacity",))
def pagerank_step(a, r, b, mask, beta, teleport, *, capacity: int):
    """One summarized-PageRank iteration over a padded dense summary graph.

    Args:
      a:        (C, C) f32 — dense padded transition matrix, A[z,u]=1/d_out(u).
      r:        (C,)   f32 — current hot-vertex ranks (padded with 0).
      b:        (C,)   f32 — per-target big-vertex contribution b_z.
      mask:     (C,)   f32 — 1.0 on valid rows, 0.0 on padding.
      beta:     scalar f32 — damping factor β.
      teleport: scalar f32 — (1-β)/n with n = |V| of the full graph.
      capacity: C, a multiple of TILE from CAPACITIES.

    Returns:
      (C,) f32 — updated ranks r'.
    """
    if capacity % TILE != 0:
        raise ValueError(f"capacity {capacity} not a multiple of {TILE}")
    c = capacity
    grid = (c // TILE, c // TILE)

    r2 = r.reshape(c, 1).astype(jnp.float32)
    b2 = b.reshape(c, 1).astype(jnp.float32)
    m2 = mask.reshape(c, 1).astype(jnp.float32)
    scalars = jnp.stack([beta, teleport]).reshape(1, 2).astype(jnp.float32)

    out = pl.pallas_call(
        _step_kernel,
        grid=grid,
        in_specs=[
            # A block (i, k): rows follow grid dim 0, cols the reduction dim.
            pl.BlockSpec((TILE, TILE), lambda i, k: (i, k)),
            # r slice follows the reduction dim.
            pl.BlockSpec((TILE, 1), lambda i, k: (k, 0)),
            # b, mask slices follow the row dim.
            pl.BlockSpec((TILE, 1), lambda i, k: (i, 0)),
            pl.BlockSpec((TILE, 1), lambda i, k: (i, 0)),
            # scalars broadcast to every step.
            pl.BlockSpec((1, 2), lambda i, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE, 1), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, 1), jnp.float32),
        interpret=True,
    )(a.astype(jnp.float32), r2, b2, m2, scalars)
    return out.reshape(c)
