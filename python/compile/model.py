"""L2 — JAX model: the summarized-PageRank compute graph VeilGraph executes.

Two exported entry points, both built on the L1 Pallas kernel
(`kernels.pagerank_step`):

* ``summarized_step``  — one power iteration.  The rust coordinator loops
  this artifact when it wants per-iteration convergence control.
* ``summarized_run``   — ``ITERS_FUSED`` iterations fused into one artifact
  with ``lax.fori_loop`` (compiled once, no unrolling) returning the final
  ranks *and* the L1 delta of the last iteration, so the coordinator can
  decide whether another fused chunk is needed without an extra round-trip.

Both are lowered per capacity by ``aot.py`` to HLO *text* and executed from
rust through PJRT.  Python never runs on the request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.pagerank_step import CAPACITIES, TILE, pagerank_step

# Iterations fused into a single `summarized_run` artifact.  The rust
# coordinator chains chunks of ITERS_FUSED until its convergence epsilon or
# iteration cap is reached.
ITERS_FUSED = 10


def summarized_step(a, r, b, mask, scalars, *, capacity: int):
    """One summarized-PageRank iteration (thin wrapper over the L1 kernel).

    `scalars` is a (2,) f32 vector [β, (1-β)/n] — packing them into one
    operand keeps the rust call-site signature stable across variants.
    Returns a 1-tuple (lowered with return_tuple=True).
    """
    beta = scalars[0]
    teleport = scalars[1]
    return (pagerank_step(a, r, b, mask, beta, teleport, capacity=capacity),)


def summarized_run(a, r, b, mask, scalars, *, capacity: int):
    """ITERS_FUSED power iterations + L1 delta of the last one.

    Returns (ranks, delta) where delta = ||r_T - r_{T-1}||_1 over valid
    rows.  fori_loop keeps the HLO compact (a while op, not an unrolled
    chain) — see DESIGN.md §Perf / ablation A6.
    """
    beta = scalars[0]
    teleport = scalars[1]

    def body(_, carry):
        r_prev, _ = carry
        r_next = pagerank_step(
            a, r_prev, b, mask, beta, teleport, capacity=capacity
        )
        delta = jnp.sum(jnp.abs(r_next - r_prev))
        return (r_next, delta)

    init = (r.astype(jnp.float32), jnp.float32(0.0))
    ranks, delta = jax.lax.fori_loop(0, ITERS_FUSED, body, init)
    return (ranks, delta)


def example_args(capacity: int):
    """Abstract argument shapes used for AOT lowering at `capacity`."""
    c = capacity
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((c, c), f32),  # a
        jax.ShapeDtypeStruct((c,), f32),    # r
        jax.ShapeDtypeStruct((c,), f32),    # b
        jax.ShapeDtypeStruct((c,), f32),    # mask
        jax.ShapeDtypeStruct((2,), f32),    # scalars [beta, teleport]
    )


VARIANTS = {
    "step": summarized_step,
    "run": summarized_run,
}

__all__ = [
    "CAPACITIES",
    "TILE",
    "ITERS_FUSED",
    "VARIANTS",
    "example_args",
    "summarized_step",
    "summarized_run",
]
