"""AOT lowering: JAX (L2+L1) → HLO text artifacts for the rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` 0.1.6 crate links) rejects
(`proto.id() <= INT_MAX`).  The text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits, per capacity C in CAPACITIES and variant in {step, run}:
    artifacts/pagerank_{variant}_c{C}.hlo.txt
plus artifacts/manifest.json describing every artifact (shapes, scalars
layout, fused iteration count) for the rust loader.
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile.model import (  # noqa: E402
    CAPACITIES,
    ITERS_FUSED,
    TILE,
    VARIANTS,
    example_args,
)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(variant: str, capacity: int) -> str:
    fn = functools.partial(VARIANTS[variant], capacity=capacity)
    lowered = jax.jit(fn).lower(*example_args(capacity))
    return to_hlo_text(lowered)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--capacities",
        default=",".join(str(c) for c in CAPACITIES),
        help="comma-separated capacities to lower",
    )
    ap.add_argument(
        "--variants", default="step,run", help="comma-separated variants"
    )
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    capacities = [int(c) for c in args.capacities.split(",") if c]
    variants = [v for v in args.variants.split(",") if v]

    manifest = {
        "format": "hlo-text",
        "tile": TILE,
        "iters_fused": ITERS_FUSED,
        "scalars_layout": ["beta", "teleport"],
        "artifacts": [],
    }

    for cap in capacities:
        for variant in variants:
            text = lower_variant(variant, cap)
            name = f"pagerank_{variant}_c{cap}.hlo.txt"
            path = os.path.join(out_dir, name)
            with open(path, "w") as f:
                f.write(text)
            digest = hashlib.sha256(text.encode()).hexdigest()[:16]
            manifest["artifacts"].append(
                {
                    "name": name,
                    "variant": variant,
                    "capacity": cap,
                    "outputs": 1 if variant == "step" else 2,
                    "sha256_16": digest,
                    "bytes": len(text),
                }
            )
            print(f"wrote {name} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
        f.write("\n")
    print(f"wrote manifest.json ({len(manifest['artifacts'])} artifacts)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
