"""L1 correctness: Pallas kernel (interpret mode) vs pure-jnp oracle.

This is the CORE correctness signal for the compute hot-spot — everything
the rust runtime executes lowers through `pagerank_step`.  Hypothesis
sweeps shapes (capacities), sparsity patterns, scalar ranges and dtypes.
"""

from __future__ import annotations

import os
import sys

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # CI installs it; bare envs skip cleanly
from hypothesis import given, settings, strategies as st

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax.numpy as jnp  # noqa: E402

from compile.kernels.pagerank_step import (  # noqa: E402
    CAPACITIES,
    TILE,
    pagerank_step,
)
from compile.kernels.ref import pagerank_step_ref  # noqa: E402

RTOL = 1e-5
ATOL = 1e-6


def random_problem(rng, capacity, n_valid, density=0.05, dtype=np.float32):
    """A random padded summary-graph problem with `n_valid` hot vertices."""
    a = np.zeros((capacity, capacity), dtype=dtype)
    if n_valid > 0:
        nnz = max(1, int(density * n_valid * n_valid))
        rows = rng.integers(0, n_valid, size=nnz)
        cols = rng.integers(0, n_valid, size=nnz)
        # val((u,z)) = 1/d_out(u) ∈ (0, 1]
        a[rows, cols] = rng.uniform(0.01, 1.0, size=nnz).astype(dtype)
    r = np.zeros(capacity, dtype=dtype)
    b = np.zeros(capacity, dtype=dtype)
    mask = np.zeros(capacity, dtype=dtype)
    r[:n_valid] = rng.uniform(0.0, 1.0, size=n_valid).astype(dtype)
    b[:n_valid] = rng.uniform(0.0, 0.5, size=n_valid).astype(dtype)
    mask[:n_valid] = 1.0
    return a, r, b, mask


def check(capacity, n_valid, beta=0.85, teleport=1e-4, seed=0, density=0.05,
          dtype=np.float32):
    rng = np.random.default_rng(seed)
    a, r, b, mask = random_problem(rng, capacity, n_valid, density, dtype)
    got = pagerank_step(
        jnp.asarray(a), jnp.asarray(r), jnp.asarray(b), jnp.asarray(mask),
        jnp.float32(beta), jnp.float32(teleport), capacity=capacity,
    )
    want = pagerank_step_ref(
        jnp.asarray(a), jnp.asarray(r), jnp.asarray(b), jnp.asarray(mask),
        jnp.float32(beta), jnp.float32(teleport),
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=RTOL, atol=ATOL)
    # Padding rows must be exactly zero (masked).
    np.testing.assert_array_equal(np.asarray(got)[n_valid:], 0.0)


@pytest.mark.parametrize("capacity", CAPACITIES)
def test_kernel_matches_ref_full_capacity(capacity):
    check(capacity, n_valid=capacity, seed=capacity)


@pytest.mark.parametrize("capacity", CAPACITIES)
def test_kernel_matches_ref_partial_fill(capacity):
    check(capacity, n_valid=capacity // 3 + 1, seed=capacity + 1)


def test_kernel_single_valid_vertex():
    check(TILE, n_valid=1, seed=7)


def test_kernel_empty_summary_is_all_zero():
    # n_valid = 0: mask kills everything, output must be identically zero.
    a = jnp.zeros((TILE, TILE), jnp.float32)
    z = jnp.zeros((TILE,), jnp.float32)
    got = pagerank_step(a, z, z, z, jnp.float32(0.85), jnp.float32(0.1),
                        capacity=TILE)
    np.testing.assert_array_equal(np.asarray(got), 0.0)


def test_kernel_zero_matrix_gives_teleport_plus_b():
    # A = 0 ⇒ r' = mask·(β·b + teleport) exactly.
    c = 2 * TILE
    rng = np.random.default_rng(3)
    _, r, b, mask = random_problem(rng, c, c // 2)
    a = jnp.zeros((c, c), jnp.float32)
    got = pagerank_step(a, jnp.asarray(r), jnp.asarray(b), jnp.asarray(mask),
                        jnp.float32(0.85), jnp.float32(0.01), capacity=c)
    want = mask * (0.85 * b + 0.01)
    np.testing.assert_allclose(np.asarray(got), want, rtol=RTOL, atol=ATOL)


def test_kernel_identity_matrix_scales_rank():
    # A = I ⇒ r' = β·(r + b) + teleport on valid rows.
    c = TILE
    rng = np.random.default_rng(9)
    _, r, b, mask = random_problem(rng, c, c)
    a = jnp.eye(c, dtype=jnp.float32)
    got = pagerank_step(a, jnp.asarray(r), jnp.asarray(b), jnp.asarray(mask),
                        jnp.float32(0.5), jnp.float32(0.25), capacity=c)
    want = 0.5 * (r + b) + 0.25
    np.testing.assert_allclose(np.asarray(got), want, rtol=RTOL, atol=ATOL)


def test_kernel_rejects_unaligned_capacity():
    a = jnp.zeros((100, 100), jnp.float32)
    z = jnp.zeros((100,), jnp.float32)
    with pytest.raises(ValueError, match="not a multiple"):
        pagerank_step(a, z, z, z, jnp.float32(0.85), jnp.float32(0.1),
                      capacity=100)


@settings(max_examples=25, deadline=None)
@given(
    cap_idx=st.integers(0, 2),            # capacities 128/256/512 for speed
    fill=st.floats(0.01, 1.0),
    beta=st.floats(0.05, 0.99),
    teleport=st.floats(1e-8, 0.5),
    density=st.floats(0.0, 0.3),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_hypothesis_sweep(cap_idx, fill, beta, teleport, density, seed):
    capacity = CAPACITIES[cap_idx]
    n_valid = max(1, int(fill * capacity))
    check(capacity, n_valid, beta=beta, teleport=teleport, seed=seed,
          density=density)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_kernel_accepts_f64_inputs_downcasts(seed):
    # dtype sweep: f64 inputs are downcast to f32 inside the kernel wrapper.
    check(TILE, n_valid=TILE // 2, seed=seed, dtype=np.float64)


def test_kernel_is_linear_in_rank():
    # r' is affine in r: step(2r) - step(r) == β·A·r on valid rows.
    c = TILE
    rng = np.random.default_rng(11)
    a, r, b, mask = random_problem(rng, c, c, density=0.1)
    s1 = pagerank_step(jnp.asarray(a), jnp.asarray(r), jnp.asarray(b),
                       jnp.asarray(mask), jnp.float32(0.85),
                       jnp.float32(0.01), capacity=c)
    s2 = pagerank_step(jnp.asarray(a), jnp.asarray(2 * r), jnp.asarray(b),
                       jnp.asarray(mask), jnp.float32(0.85),
                       jnp.float32(0.01), capacity=c)
    lin = np.asarray(s2) - np.asarray(s1)
    want = mask * (0.85 * (a @ r))
    np.testing.assert_allclose(lin, want, rtol=1e-4, atol=1e-5)
