"""L2 correctness: model variants vs oracle; shapes; fixed-point behaviour."""

from __future__ import annotations

import os
import sys

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # CI installs it; bare envs skip cleanly
from hypothesis import given, settings, strategies as st

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax.numpy as jnp  # noqa: E402

from compile.kernels.ref import (  # noqa: E402
    pagerank_iterations_ref,
    pagerank_run_ref,
)
from compile.model import (  # noqa: E402
    ITERS_FUSED,
    example_args,
    summarized_run,
    summarized_step,
)
from tests.test_kernel import random_problem  # noqa: E402

CAP = 128


def make(seed=0, n_valid=CAP, capacity=CAP, density=0.05):
    rng = np.random.default_rng(seed)
    a, r, b, mask = random_problem(rng, capacity, n_valid, density)
    scalars = np.array([0.85, 1e-3], dtype=np.float32)
    return tuple(jnp.asarray(x) for x in (a, r, b, mask, scalars))


def test_step_variant_matches_single_ref_iteration():
    a, r, b, mask, scalars = make(seed=1)
    (got,) = summarized_step(a, r, b, mask, scalars, capacity=CAP)
    want = pagerank_iterations_ref(a, r, b, mask, scalars[0], scalars[1], 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_run_variant_matches_fused_ref_iterations():
    a, r, b, mask, scalars = make(seed=2)
    ranks, delta = summarized_run(a, r, b, mask, scalars, capacity=CAP)
    want_r, want_d = pagerank_run_ref(
        a, r, b, mask, scalars[0], scalars[1], ITERS_FUSED
    )
    np.testing.assert_allclose(np.asarray(ranks), np.asarray(want_r),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(delta), float(want_d),
                               rtol=1e-3, atol=1e-5)


def test_run_converges_toward_fixed_point():
    # Chaining run artifacts must drive the L1 delta toward zero: the
    # summarized system r' = βAr + βb + t is a contraction for β<1 when
    # columns of A sum to ≤ 1.
    a, r, b, mask, scalars = make(seed=3, density=0.02)
    a = a / jnp.maximum(jnp.sum(a, axis=0, keepdims=True), 1.0)
    d_prev = None
    for _ in range(4):
        r, delta = summarized_run(a, r, b, mask, scalars, capacity=CAP)
        d = float(delta)
        if d_prev is not None:
            assert d <= d_prev + 1e-6
        d_prev = d
    assert d_prev < 1e-3


def test_example_args_shapes_cover_all_operands():
    args = example_args(256)
    assert [tuple(x.shape) for x in args] == [
        (256, 256), (256,), (256,), (256,), (2,)
    ]
    assert all(x.dtype == jnp.float32 for x in args)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), fill=st.floats(0.05, 1.0))
def test_step_hypothesis_partial_fill(seed, fill):
    n_valid = max(1, int(fill * CAP))
    a, r, b, mask, scalars = make(seed=seed, n_valid=n_valid)
    (got,) = summarized_step(a, r, b, mask, scalars, capacity=CAP)
    want = pagerank_iterations_ref(a, r, b, mask, scalars[0], scalars[1], 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    assert not np.any(np.asarray(got)[n_valid:])
