"""AOT path: lowered HLO text is parseable, deterministic, and numerically
faithful when re-executed through the XLA client (the same engine the rust
runtime drives via PJRT)."""

from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from compile.aot import lower_variant  # noqa: E402
from compile.kernels.ref import pagerank_iterations_ref  # noqa: E402
from tests.test_kernel import random_problem  # noqa: E402

CAP = 128


def test_hlo_text_is_emitted_and_looks_like_hlo():
    text = lower_variant("step", CAP)
    assert "HloModule" in text
    assert "f32[128,128]" in text
    # 64-bit-id serialized protos are the failure mode we avoid — text only.
    assert len(text) > 200


def test_hlo_lowering_is_deterministic():
    assert lower_variant("step", CAP) == lower_variant("step", CAP)


def test_run_variant_has_while_loop_not_unrolled():
    text = lower_variant("run", CAP)
    assert "while" in text  # fori_loop must stay a while op (perf: A6)


def test_lowered_step_executes_and_matches_ref():
    """Round-trip: HLO text → parse → compile (CPU client) → execute.

    This mirrors exactly what rust/src/runtime does via the xla crate.
    """
    text = lower_variant("step", CAP)
    client = xc.make_cpu_client()
    hlo = xc._xla.hlo_module_from_text(text)
    comp = xc.XlaComputation(hlo.as_serialized_hlo_module_proto())
    mlir = xc._xla.mlir.xla_computation_to_mlir_module(comp)
    devices = xc._xla.DeviceList(tuple(client.local_devices()))
    exe = client.compile_and_load(mlir, devices)

    rng = np.random.default_rng(42)
    a, r, b, mask = random_problem(rng, CAP, CAP // 2)
    scalars = np.array([0.85, 1e-3], dtype=np.float32)
    outs = exe.execute_sharded(
        [client.buffer_from_pyval(x) for x in (a, r, b, mask, scalars)]
    )
    got = np.asarray(outs.disassemble_into_single_device_arrays()[0][0])
    want = pagerank_iterations_ref(
        jnp.asarray(a), jnp.asarray(r), jnp.asarray(b), jnp.asarray(mask),
        0.85, 1e-3, 1,
    )
    np.testing.assert_allclose(got, np.asarray(want), rtol=1e-5, atol=1e-6)


def test_aot_cli_writes_artifacts_and_manifest(tmp_path):
    env = dict(os.environ)
    repo_py = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--capacities", "128", "--variants", "step"],
        cwd=repo_py, env=env, capture_output=True, text=True,
    )
    assert out.returncode == 0, out.stderr
    files = sorted(os.listdir(tmp_path))
    assert "manifest.json" in files
    assert "pagerank_step_c128.hlo.txt" in files
    manifest = json.load(open(tmp_path / "manifest.json"))
    (art,) = manifest["artifacts"]
    assert art["capacity"] == 128 and art["variant"] == "step"
    assert manifest["scalars_layout"] == ["beta", "teleport"]
