//! SLA tiers over a streaming web graph — the paper's §1 motivation
//! (“SLAs for graph processing, with different tiers of accuracy and
//! resource efficiency”) made concrete.
//!
//! Runs the same update stream through Gold (always exact), Silver
//! (approximate + periodic exact refresh) and Bronze (approximate,
//! repeat-on-tiny-updates) engines and reports the accuracy/latency
//! trade-off of each tier.
//!
//!     cargo run --release --example web_sla

use veilgraph::coordinator::engine::EngineBuilder;
use veilgraph::coordinator::policies::{SlaPolicy, SlaTier};
use veilgraph::coordinator::udf::Action;
use veilgraph::graph::generate;
use veilgraph::metrics::rbo::rbo_ext;
use veilgraph::stream::source::{chunked_events, split_stream};
use veilgraph::summary::params::SummaryParams;

fn main() -> veilgraph::error::Result<()> {
    // A web crawl stand-in and a held-out update stream (paper protocol).
    let web = generate::copying_web(20_000, 10, 0.7, 2024);
    let (initial, stream) = split_stream(&web, 4_000, true, 7);
    let events = chunked_events(&stream, 20);
    println!(
        "web graph: {} initial edges, {} streamed in 20 query chunks\n",
        initial.len(),
        stream.len()
    );

    let tiers = [
        ("gold  ", SlaTier::Gold),
        ("silver", SlaTier::Silver { refresh: 5 }),
        ("bronze", SlaTier::Bronze),
    ];

    // Ground truth for accuracy scoring: gold IS the ground truth, so run
    // it first and keep its rankings.
    let mut gold_rankings: Vec<Vec<u64>> = Vec::new();
    println!("{:<7} {:>10} {:>10} {:>9} {:>8} {:>8} {:>8}", "tier", "total(ms)", "p-avg(ms)", "avgRBO", "exact", "approx", "repeat");
    for (name, tier) in tiers {
        let mut engine = EngineBuilder::new()
            .params(SummaryParams::new(0.2, 1, 0.1))
            .udf(Box::new(SlaPolicy { tier }))
            .build_from_edges(initial.iter().copied())?;
        let results = engine.run_stream(events.clone())?;
        let total: f64 = results.iter().map(|r| r.exec.elapsed_secs).sum();
        let (mut n_exact, mut n_approx, mut n_repeat) = (0, 0, 0);
        for r in &results {
            match r.action {
                Action::ComputeExact => n_exact += 1,
                Action::ComputeApproximate => n_approx += 1,
                Action::RepeatLast => n_repeat += 1,
            }
        }
        let mut rbo_avg = 0.0;
        if gold_rankings.is_empty() {
            gold_rankings = results.iter().map(|r| r.top_ids(1_000)).collect();
            rbo_avg = 1.0;
        } else {
            for (r, gold) in results.iter().zip(&gold_rankings) {
                rbo_avg += rbo_ext(&r.top_ids(1_000), gold, 0.99);
            }
            rbo_avg /= results.len() as f64;
        }
        println!(
            "{name} {:>10.1} {:>10.2} {:>9.4} {:>8} {:>8} {:>8}",
            total * 1e3,
            total * 1e3 / results.len() as f64,
            rbo_avg,
            n_exact,
            n_approx,
            n_repeat
        );
    }
    println!("\ngold = ground truth; silver trades ~tiny accuracy for large speedups;");
    println!("bronze adds repeat-last on negligible updates (cheapest, least fresh).");
    Ok(())
}
