//! TRENDING FEED — standing queries driving a live "what's hot" ticker.
//!
//! The serving story so far was pull: clients poll `top` against the
//! published snapshot. This example inverts it with protocol v2's push
//! plane: a feed client registers one standing `topk` subscription and
//! then just reads its socket — every time the engine publishes a
//! snapshot whose top-K membership changed, a push frame arrives with
//! exactly who entered and who left. Combined with a sliding window on
//! the write path, "trending" falls out for free: an item stops being
//! reinforced, its edges expire as generated `RemoveEdge` batches, its
//! rank sinks, and the subscription reports it leaving the chart.
//!
//!     cargo run --release --example trending_feed
//!
//! Wire traffic (one JSON object per line):
//!
//!     → {"v":2,"id":1,"op":"subscribe","what":"topk","k":5}
//!     ← {"v":2,"ok":true,"id":1,"sub":1}
//!     ← {"v":2,"sub":1,"notify":{"kind":"topk","k":5,"version":7,
//!        "entered":[40012],"left":[17]}}          (pushed, not polled)

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use veilgraph::coordinator::engine::EngineBuilder;
use veilgraph::coordinator::server::{serve_shared, ServeOptions, ServerHandle};
use veilgraph::graph::generate;
use veilgraph::stream::event::EdgeOp;
use veilgraph::summary::params::SummaryParams;
use veilgraph::util::json::Json;

const CHART_K: usize = 5;
/// Stories: vertices that user-interaction edges point at. A burst of
/// edges into a story is "engagement"; the 2-second window means
/// engagement stops counting 2s after it happened.
const STORIES: std::ops::Range<u64> = 100_000..100_008;

fn send(c: &mut TcpStream, line: &str) {
    c.write_all(line.as_bytes()).unwrap();
    c.write_all(b"\n").unwrap();
}

fn main() -> veilgraph::error::Result<()> {
    // A background web graph plus eight initially-cold story vertices.
    let mut edges = generate::copying_web(20_000, 8, 0.7, 42);
    for s in STORIES {
        edges.push((s, s % 20_000));
    }
    let engine = EngineBuilder::new()
        .params(SummaryParams::new(0.2, 1, 0.1))
        .build_from_edges(edges)?;

    // The push plane needs nothing special server-side — subscriptions
    // hang off the publisher. The 2-second sliding window is the only
    // serving knob this example turns on.
    let opts = ServeOptions::new().workers(2).window_secs(2.0);
    let h = Arc::new(ServerHandle::spawn_with(engine, &opts));
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let server = {
        let h2 = Arc::clone(&h);
        let o = ServeOptions::new().workers(2).window_secs(2.0);
        std::thread::spawn(move || serve_shared(h2, listener, o).unwrap())
    };

    // ---- the feed client: subscribe once, then only read ---------------
    let done = Arc::new(AtomicBool::new(false));
    let feed = {
        let done2 = Arc::clone(&done);
        std::thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            c.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
            let mut r = BufReader::new(c.try_clone().unwrap());
            let sub = format!(r#"{{"v":2,"id":1,"op":"subscribe","what":"topk","k":{CHART_K}}}"#);
            send(&mut c, &sub);
            let t0 = Instant::now();
            let mut line = String::new();
            while !done2.load(Ordering::Relaxed) {
                line.clear();
                if r.read_line(&mut line).is_err() || line.is_empty() {
                    continue; // timeout tick: check the stop flag
                }
                let frame = Json::parse(line.trim()).unwrap();
                let Some(body) = frame.get("notify") else {
                    continue; // the subscribe ack
                };
                let names = |key: &str| -> Vec<u64> {
                    body.get(key)
                        .and_then(Json::as_arr)
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(Json::as_u64)
                        .collect()
                };
                println!(
                    "[{:>6.2}s] chart v{:<4} in: {:?} out: {:?}",
                    t0.elapsed().as_secs_f64(),
                    body.get("version").and_then(Json::as_u64).unwrap_or(0),
                    names("entered"),
                    names("left"),
                );
            }
        })
    };

    // ---- the world: engagement bursts, then silence ---------------------
    // Each story gets a burst of inbound edges (readers linking to it),
    // then the stream moves on. While a burst is inside the window the
    // story climbs; once its edges expire it falls back off the chart —
    // without anyone sending a RemoveEdge.
    let mut writer = TcpStream::connect(addr)?;
    writer.set_read_timeout(Some(Duration::from_secs(10)))?;
    let mut wr = BufReader::new(writer.try_clone()?);
    let mut ack = String::new();
    for (round, story) in STORIES.enumerate() {
        let ops: Vec<String> = (0..400u64)
            .map(|i| {
                let reader = 200_000 + round as u64 * 400 + i;
                format!(r#"{{"op":"add","src":{reader},"dst":{story}}}"#)
            })
            .collect();
        send(&mut writer, &format!(r#"{{"op":"batch","ops":[{}]}}"#, ops.join(",")));
        ack.clear();
        wr.read_line(&mut ack)?;
        // A query drives the staleness decision; the recompute runs
        // off-thread and its publish is what fires the push frames.
        send(&mut writer, r#"{"v":2,"id":9,"op":"query","top":5}"#);
        ack.clear();
        wr.read_line(&mut ack)?;
        std::thread::sleep(Duration::from_millis(700));
    }
    // Keep querying with no new engagement: the window drains the bursts
    // and the chart resets to the background graph's steady state.
    for _ in 0..6 {
        send(&mut writer, r#"{"v":2,"id":9,"op":"query","top":5}"#);
        ack.clear();
        wr.read_line(&mut ack)?;
        std::thread::sleep(Duration::from_millis(500));
    }

    done.store(true, Ordering::Relaxed);
    feed.join().unwrap();
    send(&mut writer, r#"{"op":"shutdown"}"#);
    ack.clear();
    wr.read_line(&mut ack)?;
    server.join().unwrap();
    Ok(())
}
