//! Quickstart: build a graph, stream edge updates, serve approximate
//! PageRank queries, and inspect what the engine did.
//!
//!     cargo run --release --example quickstart

use veilgraph::coordinator::engine::EngineBuilder;
use veilgraph::graph::generate;
use veilgraph::stream::event::EdgeOp;
use veilgraph::summary::params::SummaryParams;

fn main() -> veilgraph::error::Result<()> {
    // A small scale-free graph: 1 000 vertices, preferential attachment.
    let edges = generate::barabasi_albert(1_000, 3, 0.5, 42);
    println!("initial graph: {} edges", edges.len());

    // The model parameters (r, n, Δ) — Fig. 1's knobs:
    //   r = 0.2  → vertices whose degree changed >20 % become hot (K_r)
    //   n = 1    → plus their 1-hop neighborhoods (K_n)
    //   Δ = 0.1  → plus score-weighted extra hops (K_Δ, Eq. 5)
    let mut engine = EngineBuilder::new()
        .params(SummaryParams::new(0.2, 1, 0.1))
        .build_from_edges(edges)?;
    println!("initial exact PageRank done (measurement point 0)\n");

    // Stream three batches of updates, querying after each (Alg. 1).
    // `ingest_batch` registers each batch in one call; the apply step
    // coalesces it (duplicates collapse, add+remove pairs cancel) before
    // mutating the graph row-by-row.
    for batch in 0..3u64 {
        // new vertices attaching to the old core
        let ops: Vec<EdgeOp> =
            (0..25u64).map(|i| EdgeOp::add(2_000 + batch * 100 + i, i * 7 % 500)).collect();
        engine.ingest_batch(ops);
        let result = engine.query()?;
        println!(
            "query {}: action={}, |K|={} of {} vertices ({:.1}%), \
             summary edges={}, {:.2}ms",
            result.query_id,
            result.action,
            result.exec.summary_vertices,
            result.ids().len(),
            100.0 * result.exec.summary_vertices as f64 / result.ids().len() as f64,
            result.exec.summary_edges,
            result.exec.elapsed_secs * 1e3,
        );
        println!("  top-5: {:?}", result.top(5));
    }

    println!("\nengine metrics:\n{}", engine.metrics().to_json().to_string_pretty());
    Ok(())
}
