//! END-TO-END DRIVER — proves all three layers compose on a realistic
//! workload and reports the paper's headline metric.
//!
//! Pipeline exercised:
//!   1. dataset stand-in generation (cit-hepph, the 1:1-scale dataset);
//!   2. paper protocol: hold out |S| edges, chunk into Q queries;
//!   3. VeilGraph engine with the **XLA backend** — hot-vertex selection
//!      (L3, rust) → summary densification → AOT Pallas PageRank kernel
//!      (L1, lowered through the L2 JAX model to HLO text) executed via
//!      PJRT — python never runs here;
//!   4. exact ground-truth replay for accuracy/speedup scoring;
//!   5. headline: computation reduction at RBO accuracy (paper §Abstract:
//!      “over 50 % time reduction with result quality above 95 %”).
//!
//!     make artifacts && cargo run --release --example end_to_end
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use veilgraph::coordinator::engine::EngineBuilder;
use veilgraph::coordinator::policies::{AlwaysApproximate, AlwaysExact};
use veilgraph::experiments::datasets::dataset_by_name;
use veilgraph::metrics::ranking::rbo_depth_for_density;
use veilgraph::metrics::rbo::rbo_ext;
use veilgraph::pagerank::power::PageRankConfig;
use veilgraph::runtime::executor::Backend;
use veilgraph::stream::event::{EdgeOp, UpdateEvent};
use veilgraph::stream::source::{chunked_events, split_stream, update_density};
use veilgraph::summary::params::SummaryParams;
use veilgraph::util::timer::Stopwatch;

fn main() -> veilgraph::error::Result<()> {
    let scale: f64 =
        std::env::var("VEILGRAPH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.35);
    let q = 50usize;

    // ---- 1. workload ---------------------------------------------------
    let spec = dataset_by_name("cit-hepph").unwrap();
    let edges = spec.generate(scale);
    let stream_len = spec.stream_len_at(scale);
    println!(
        "workload: {} (stand-in for {}), {} edges at scale {scale}",
        spec.name,
        spec.paper_name,
        edges.len()
    );

    // ---- 2. paper protocol ----------------------------------------------
    let (initial, stream) = split_stream(&edges, stream_len, false, 7);
    let events = chunked_events(&stream, q);
    let density = update_density(stream.len(), q);
    let depth = rbo_depth_for_density(density);
    println!(
        "stream: |S|={} in Q={q} chunks (density {density:.0} edges/query, RBO depth {depth})\n",
        stream.len()
    );

    // ---- 3. approximate engine with the XLA backend ---------------------
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").is_file() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let pr = PageRankConfig { epsilon: 1e-8, max_iters: 100, ..Default::default() };
    let build = Stopwatch::start();
    let mut approx = EngineBuilder::new()
        .params(SummaryParams::new(0.2, 1, 0.1))
        .pagerank(pr)
        .udf(Box::new(AlwaysApproximate))
        .artifacts_dir(&artifacts)
        .warmup(true)
        .build_from_edges(initial.iter().copied())?;
    println!(
        "approximate engine up in {:.2}s (XLA tiers compiled: {}, initial exact PageRank done)",
        build.secs(),
        approx.has_xla()
    );
    // Ground truth = the paper's baseline: complete (cold) PageRank per
    // query. (Our engine can also warm-start exact queries — that harder
    // baseline is measured in ablation A7.)
    let pr_cold = PageRankConfig { warm_start_exact: false, ..pr };
    let mut exact = EngineBuilder::new()
        .udf(Box::new(AlwaysExact))
        .pagerank(pr_cold)
        .build_from_edges(initial.iter().copied())?;

    // ---- 4. replay -------------------------------------------------------
    let mut rows = Vec::new();
    let mut events = events.into_iter();
    let mut xla_queries = 0usize;
    loop {
        // step to the next query boundary, shipping each op run into
        // BOTH engines as one coalescible batch (the write path's wire
        // shape) — one event cursor drives the pair
        let mut query_now = false;
        let mut batch: Vec<EdgeOp> = Vec::new();
        for ev in events.by_ref() {
            match ev {
                UpdateEvent::Op(op) => batch.push(op),
                UpdateEvent::Query => {
                    query_now = true;
                    break;
                }
                UpdateEvent::Stop => break,
            }
        }
        if !batch.is_empty() {
            approx.ingest_batch(batch.iter().copied());
            exact.ingest_batch(batch);
        }
        if !query_now {
            break;
        }
        let ra = approx.query()?;
        let re = exact.query()?;
        if matches!(ra.exec.backend, Some(Backend::XlaDense { .. })) {
            xla_queries += 1;
        }
        let rbo = rbo_ext(&ra.top_ids(depth), &re.top_ids(depth), 0.99);
        rows.push((ra, re, rbo));
        let (ra, re, rbo) = rows.last().unwrap();
        if rows.len() % 10 == 0 || rows.len() == 1 {
            println!(
                "q{:>2}: |K|={:>5}/{:<6} backend={} approx={:>7.2}ms exact={:>8.2}ms speedup={:>5.1}x rbo={:.4}",
                ra.query_id,
                ra.exec.summary_vertices,
                ra.ids().len(),
                ra.exec
                    .backend
                    .map(|b| b.to_string())
                    .unwrap_or_else(|| "none".into()),
                ra.exec.elapsed_secs * 1e3,
                re.exec.elapsed_secs * 1e3,
                re.exec.elapsed_secs / ra.exec.elapsed_secs,
                rbo
            );
        }
    }

    // ---- 5. three-layer composition proof --------------------------------
    // (a) an engine-served query whose summarized computation runs on the
    //     AOT Pallas/HLO artifact through PJRT (backend must be XlaDense);
    // (b) numeric cross-check: the same summary through the XLA artifact
    //     and the sparse oracle must agree to f32 precision.
    {
        use veilgraph::graph::dynamic::DynamicGraph;
        use veilgraph::pagerank::summarized::run_summarized;
        use veilgraph::runtime::executor::SummarizedExecutor;
        use veilgraph::stream::event::EdgeOp;
        use veilgraph::summary::bigvertex::SummaryGraph;
        use veilgraph::summary::hot::HotSet;

        // (a) small-K workload: aggressive params keep |K| within the
        // cost-effective XLA tier on this CPU (DEFAULT_MAX_XLA_K).
        let small = veilgraph::graph::generate::barabasi_albert(2_000, 3, 0.5, 31);
        let mut eng = EngineBuilder::new()
            .params(SummaryParams::new(0.3, 0, 0.9))
            .pagerank(pr)
            .artifacts_dir(&artifacts)
            .warmup(true)
            .build_from_edges(small.iter().copied())?;
        eng.ingest_many((0..40u64).map(|i| EdgeOp::add(3_000 + i, i % 200)));
        let r = eng.query()?;
        println!(
            "
engine-served XLA query: |K|={} backend={} in {:.2}ms",
            r.exec.summary_vertices,
            r.exec.backend.map(|b| b.to_string()).unwrap_or_else(|| "none".into()),
            r.exec.elapsed_secs * 1e3
        );
        assert!(
            matches!(r.exec.backend, Some(Backend::XlaDense { .. })),
            "expected the XLA backend, got {:?}",
            r.exec.backend
        );

        // (b) numeric cross-check at c512.
        let vg = veilgraph::graph::generate::barabasi_albert(450, 3, 0.5, 31);
        let (g2, _) = DynamicGraph::from_edges(vg);
        let n2 = g2.num_vertices();
        let idxs: Vec<u32> = (0..n2 as u32).collect();
        let hs = HotSet { k_r: idxs, k_n: vec![], k_delta: vec![], hot: vec![true; n2] };
        let s2 = SummaryGraph::build(&g2, &hs, &vec![1.0; n2], 1.0);
        let sparse = run_summarized(&s2, &pr);
        let mut exec = SummarizedExecutor::with_artifacts(&artifacts)?;
        exec.set_max_xla_k(usize::MAX);
        let sw = Stopwatch::start();
        let (dense, backend) = exec.execute(&s2, &pr)?;
        let max_diff = sparse
            .ranks
            .iter()
            .zip(&dense.ranks)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        println!(
            "cross-backend validation: |K|={} via {} in {:.1}ms, max |xla - sparse| = {max_diff:.2e}",
            s2.num_vertices(),
            backend,
            sw.secs() * 1e3
        );
        assert!(max_diff < 1e-4, "backends disagree");
    }

    // ---- 6. headline ------------------------------------------------------
    let qn = rows.len() as f64;
    let approx_total: f64 = rows.iter().map(|(a, _, _)| a.exec.elapsed_secs).sum();
    let exact_total: f64 = rows.iter().map(|(_, e, _)| e.exec.elapsed_secs).sum();
    let rbo_avg: f64 = rows.iter().map(|(_, _, r)| r).sum::<f64>() / qn;
    let rbo_final = rows.last().unwrap().2;
    let vr_avg: f64 = rows
        .iter()
        .map(|(a, _, _)| a.exec.summary_vertices as f64 / a.ids().len() as f64)
        .sum::<f64>()
        / qn;
    let reduction = 100.0 * (1.0 - approx_total / exact_total);
    println!("\n================ headline ================");
    println!("queries served:            {} ({} on the XLA backend)", rows.len(), xla_queries);
    println!("avg summary vertex ratio:  {:.2}%", vr_avg * 100.0);
    println!("total exact time:          {:.1}ms", exact_total * 1e3);
    println!("total approximate time:    {:.1}ms", approx_total * 1e3);
    println!("computation reduction:     {reduction:.1}%  (paper: >50 %)");
    println!("mean speedup:              {:.2}x", exact_total / approx_total);
    println!("avg RBO:                   {rbo_avg:.4}  (paper: >0.95)");
    println!("final RBO after Q={q}:     {rbo_final:.4}");
    let ok = reduction > 50.0 && rbo_avg > 0.95;
    println!("paper claim reproduced:    {}", if ok { "YES" } else { "NO" });
    std::process::exit(if ok { 0 } else { 2 });
}
