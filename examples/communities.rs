//! Streaming community detection — the paper's §7 extension realized:
//! the VeilGraph model (hot vertices + frozen remainder) applied to
//! label-propagation community detection on an evolving social network.
//!
//!     cargo run --release --example communities

use veilgraph::community::labelprop::{label_propagation, pair_agreement};
use veilgraph::community::streaming::StreamingCommunities;
use veilgraph::coordinator::udf::Action;
use veilgraph::graph::dynamic::DynamicGraph;
use veilgraph::graph::generate;
use veilgraph::stream::event::EdgeOp;
use veilgraph::summary::params::SummaryParams;
use veilgraph::util::timer::Stopwatch;

fn main() -> veilgraph::error::Result<()> {
    // An ego-style network: dense core plus periphery.
    let edges = generate::ego_network(5_000, 250, 0.3, 6, 77);
    println!("network: {} edges", edges.len());

    let mut streaming = StreamingCommunities::new(
        edges.iter().copied(),
        SummaryParams::new(0.15, 1, 0.1),
        30,
    )?;
    println!(
        "initial communities: {} (exact label propagation)\n",
        {
            let mut labels = streaming.labels().to_vec();
            labels.sort_unstable();
            labels.dedup();
            labels.len()
        }
    );

    println!(
        "{:>5} {:>8} {:>8} {:>10} {:>10} {:>10}",
        "query", "|K|", "sweeps", "approx(ms)", "exact(ms)", "agreement"
    );
    for batch in 0..6u64 {
        // 150 new members join, attaching to the core (plus some churn)
        for i in 0..150u64 {
            let member = 10_000 + batch * 1_000 + i;
            streaming.ingest(EdgeOp::add(member, i % 250));
            streaming.ingest(EdgeOp::add(i % 250, member));
        }
        let r = streaming.query(Action::ComputeApproximate)?;

        // exact reference on the same (post-update) topology
        let sw = Stopwatch::start();
        let reference = {
            let mut g = DynamicGraph::new();
            for (s, d) in streaming.graph().edges() {
                let _ = g.add_edge(streaming.graph().id(s), streaming.graph().id(d));
            }
            label_propagation(&g, 30)
        };
        let exact_ms = sw.secs() * 1e3;
        let agree = pair_agreement(&r.labels, &reference.labels, 50_000, batch);
        println!(
            "{:>5} {:>8} {:>8} {:>10.2} {:>10.2} {:>10.4}",
            r.query_id,
            r.hot_vertices,
            r.sweeps,
            r.elapsed_secs * 1e3,
            exact_ms,
            agree
        );
    }
    println!("\nstreaming label propagation recomputes only the hot set yet stays");
    println!("in near-total co-membership agreement with the full recomputation.");
    Ok(())
}
