//! Social-feed serving: concurrent producers push follow-edges into the
//! threaded query server while clients query influencer rankings —
//! exercising the server, the bounded ingestion queue, backpressure
//! counters (Fig. 2's deployment shape) and the read/write split: a
//! board-reader thread serves top-k lookups from the published snapshot
//! the whole time, without ever entering the engine queue.
//!
//!     cargo run --release --example social_feed

use std::sync::Arc;

use veilgraph::coordinator::engine::EngineBuilder;
use veilgraph::coordinator::server::ServerHandle;
use veilgraph::graph::generate;
use veilgraph::stream::backpressure::OverflowPolicy;
use veilgraph::stream::event::EdgeOp;
use veilgraph::summary::params::SummaryParams;
use veilgraph::util::rng::Xoshiro256pp;
use veilgraph::util::timer::Stopwatch;

fn main() -> veilgraph::error::Result<()> {
    // A social network stand-in (reciprocal preferential attachment).
    let n0 = 10_000u64;
    let base = generate::barabasi_albert(n0 as usize, 4, 0.7, 99);
    println!("social graph: {} follow edges", base.len());
    let engine = EngineBuilder::new()
        .params(SummaryParams::new(0.2, 1, 0.1))
        .build_from_edges(base)?;
    let server = Arc::new(ServerHandle::spawn(engine, 8_192, OverflowPolicy::Block));

    // 4 producer threads: new users following existing accounts. Each
    // producer ships its follows as atomic 64-op batches — one queue
    // slot per batch instead of one per follow (the wire `batch` op in
    // miniature).
    let producers: Vec<_> = (0..4u64)
        .map(|t| {
            let s = Arc::clone(&server);
            std::thread::spawn(move || {
                let mut rng = Xoshiro256pp::new(1000 + t);
                let mut batch: Vec<EdgeOp> = Vec::with_capacity(64);
                for i in 0..2_000u64 {
                    let new_user = 100_000 + t * 10_000 + i;
                    // follow 1-3 popular accounts (low ids are oldest/hubs)
                    for _ in 0..rng.range(1, 4) {
                        let target = rng.next_below(n0 / 10);
                        batch.push(EdgeOp::add(new_user, target));
                    }
                    if batch.len() >= 64 {
                        let _ = s.ingest_batch(std::mem::take(&mut batch));
                    }
                }
                if !batch.is_empty() {
                    let _ = s.ingest_batch(batch);
                }
            })
        })
        .collect();

    // 1 client thread: queries the influencer board while updates land.
    let client = {
        let s = Arc::clone(&server);
        std::thread::spawn(move || {
            let mut lat = Vec::new();
            for q in 0..8 {
                std::thread::sleep(std::time::Duration::from_millis(60));
                let sw = Stopwatch::start();
                let r = s.query().expect("query");
                lat.push(sw.secs());
                println!(
                    "query {:>2}: |V|={:>6} |K|={:>5} action={} {:.1}ms  top-3 {:?}",
                    q + 1,
                    r.ids().len(),
                    r.exec.summary_vertices,
                    r.action,
                    r.exec.elapsed_secs * 1e3,
                    r.top(3).iter().map(|(v, _)| *v).collect::<Vec<_>>()
                );
            }
            lat
        })
    };

    // 1 board-reader thread: lock-free top-3 reads off the published
    // snapshot while the writer is busy — the read path at work.
    let board = {
        let reader = server.reader();
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let t = std::thread::spawn(move || {
            let mut reads = 0u64;
            while !stop2.load(std::sync::atomic::Ordering::Relaxed) {
                let _board = reader.top(3);
                reads += 1;
            }
            reads
        });
        (t, stop)
    };

    for p in producers {
        p.join().unwrap();
    }
    let lat = client.join().unwrap();
    board.1.store(true, std::sync::atomic::Ordering::Relaxed);
    let reads = board.0.join().unwrap();
    let stats = server.stats()?;
    println!("\nserved {} queries while ingesting ~24k ops from 4 threads", lat.len());
    println!("board reader served {reads} top-3 lookups off-queue meanwhile");
    println!(
        "mean query latency {:.1}ms; engine metrics:\n{}",
        lat.iter().sum::<f64>() / lat.len() as f64 * 1e3,
        stats.to_string_pretty()
    );
    Ok(())
}
